//! The canonical (world-agnostic) optimizer-state form.
//!
//! Execution modes serialize optimizer state differently: a single-process
//! run exports one full-tensor blob, a DDP cluster exports rank 0's
//! replica, and an FSDP cluster exports one *shard-local* frame per rank.
//! Before this module, FSDP resume therefore hard-required the same world
//! size — an elastic restart (resume at a different `--world`, or switch
//! between `--parallel` modes) was impossible.
//!
//! [`CanonicalOptState`] fixes that by normalizing everything to one form
//! at checkpoint time:
//!
//! * **Full** — the single-process blob: full-tensor moments, the
//!   optimizer's RNG stream position, Q-GaLore's lazy-gate state. FSDP
//!   exports are *gathered* into this form (per-rank moment shards are
//!   concatenated along each parameter's shard axis; the leader's
//!   SVD-stream position becomes the canonical stream), and on import the
//!   form is *re-sliced* for any target world — including world 1,
//!   non-power-of-two worlds, and worlds that leave some ranks with empty
//!   shards.
//! * **PerRank** — the escape hatch for optimizers whose state cannot be
//!   re-sliced bit-exactly (block-quantized Adam8bit moments, Adafactor's
//!   factored accumulators): the raw per-rank frames ride along
//!   world-locked, and any cross-world import fails loudly instead of
//!   silently resetting moments.
//!
//! The gather/scatter pair is the identity on the canonical form, and for
//! the re-shardable optimizers (AdamW, SGDM, GaLore, Q-GaLore) the
//! canonical bytes are *identical* no matter which mode or world exported
//! them — `tests/resharding.rs` pins both properties.

use crate::dist::{shard_axis, shard_bounds, ParamMeta, ShardAxis};
use crate::optim::ser::{push_f32s, push_u64, Reader};
use crate::util::rng::Pcg64;

/// Header identifying a canonical optimizer-state blob (v3 checkpoints).
/// Legacy (v2) payloads — raw single-process blobs or FSDP `[world]`-framed
/// blobs — never start with these bytes (they begin with a small
/// little-endian counter), so [`CanonicalOptState::sniff`] is unambiguous.
pub const MAGIC: &[u8; 8] = b"GAL2OPT\x01";

const FLAVOR_FULL: u64 = 0;
const FLAVOR_PER_RANK: u64 = 1;

/// Optimizer names whose state the canonical form can re-slice for an
/// arbitrary FSDP world.
pub const RESHARDABLE: &[&str] = &["adamw", "sgdm", "galore", "qgalore"];

/// The payload of a canonical optimizer state.
#[derive(Clone, Debug, PartialEq)]
pub enum OptPayload {
    /// World-agnostic full-tensor blob in the single-process format.
    Full(Vec<u8>),
    /// World-locked raw per-rank frames (non-re-shardable optimizers).
    PerRank { frames: Vec<Vec<u8>> },
}

/// A checkpoint's optimizer state, normalized away from the execution mode
/// and world size that produced it.
#[derive(Clone, Debug, PartialEq)]
pub struct CanonicalOptState {
    /// Optimizer name (`OptimizerSpec::name`): imports cross-check it so a
    /// galore checkpoint can never silently feed adamw moments.
    pub name: String,
    pub payload: OptPayload,
}

impl CanonicalOptState {
    /// Whether `bytes` carry the canonical header (v3) — as opposed to a
    /// legacy (v2) mode-specific blob.
    pub fn sniff(bytes: &[u8]) -> bool {
        bytes.len() >= MAGIC.len() && &bytes[..MAGIC.len()] == MAGIC
    }

    /// Wrap a single-process/DDP full-tensor blob already in the
    /// canonical layout for `name`. Prefer [`CanonicalOptState::from_full`],
    /// which converts from the exporting optimizer's layout.
    pub fn full(name: &str, blob: Vec<u8>) -> CanonicalOptState {
        CanonicalOptState {
            name: name.to_string(),
            payload: OptPayload::Full(blob),
        }
    }

    /// Wrap a full-tensor blob serialized in `codec` layout (see
    /// [`OptimizerSpec::state_codec`]) into the canonical layout for
    /// `name`: "qgalore"-named state is canonically Q-GaLore-framed even
    /// when the exporting optimizer was a concrete `GaLore` holding the
    /// raw layout (the quantized-projector GaLore spec, whose name is
    /// also "qgalore").
    ///
    /// [`OptimizerSpec::state_codec`]: crate::optim::OptimizerSpec::state_codec
    pub fn from_full(name: &str, codec: &str, blob: Vec<u8>) -> CanonicalOptState {
        let blob = if name == "qgalore" && codec == "galore" {
            wrap_qgalore(blob)
        } else {
            blob
        };
        CanonicalOptState::full(name, blob)
    }

    /// The full-tensor blob converted to the importing optimizer's
    /// `codec` layout (the lazy-gate state is dropped when a framed
    /// "qgalore" blob feeds a concrete `GaLore`, mirroring FSDP's inert
    /// gate).
    pub fn to_full_for(&self, codec: &str) -> Result<Vec<u8>, String> {
        let blob = self.to_full()?;
        if self.name == "qgalore" && codec == "galore" {
            unwrap_qgalore(&blob)
        } else {
            Ok(blob)
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        push_u64(&mut out, self.name.len() as u64);
        out.extend_from_slice(self.name.as_bytes());
        match &self.payload {
            OptPayload::Full(blob) => {
                push_u64(&mut out, FLAVOR_FULL);
                push_u64(&mut out, blob.len() as u64);
                out.extend_from_slice(blob);
            }
            OptPayload::PerRank { frames } => {
                push_u64(&mut out, FLAVOR_PER_RANK);
                push_u64(&mut out, frames.len() as u64);
                for f in frames {
                    push_u64(&mut out, f.len() as u64);
                    out.extend_from_slice(f);
                }
            }
        }
        out
    }

    pub fn decode(bytes: &[u8]) -> Result<CanonicalOptState, String> {
        if !Self::sniff(bytes) {
            return Err(
                "not a canonical optimizer-state blob (missing GAL2OPT header); \
                 legacy (v2) checkpoints store mode-specific state instead"
                    .into(),
            );
        }
        let mut r = Reader::new(&bytes[MAGIC.len()..]);
        let name_len = r.u64()? as usize;
        let name = String::from_utf8(r.bytes(name_len)?.to_vec())
            .map_err(|_| "canonical state: optimizer name is not utf-8".to_string())?;
        let payload = match r.u64()? {
            FLAVOR_FULL => {
                let len = r.u64()? as usize;
                OptPayload::Full(r.bytes(len)?.to_vec())
            }
            FLAVOR_PER_RANK => {
                let world = r.u64()? as usize;
                // Each frame needs at least its 8-byte length header:
                // bound the count before allocating, so a corrupt u64
                // yields an Err instead of a capacity-overflow abort.
                if world > r.remaining() / 8 {
                    return Err(format!(
                        "canonical state: per-rank frame count {world} exceeds blob size"
                    ));
                }
                let mut frames = Vec::with_capacity(world);
                for _ in 0..world {
                    let len = r.u64()? as usize;
                    frames.push(r.bytes(len)?.to_vec());
                }
                OptPayload::PerRank { frames }
            }
            other => return Err(format!("canonical state: unknown flavor {other}")),
        };
        Ok(CanonicalOptState { name, payload })
    }

    /// Fail unless the checkpoint's optimizer matches the running one.
    pub fn expect_name(&self, want: &str) -> Result<(), String> {
        if self.name == want {
            Ok(())
        } else {
            Err(format!(
                "checkpoint holds {} optimizer state but this run uses {want}; \
                 restart with --optimizer {} (or retrain)",
                self.name, self.name
            ))
        }
    }

    /// Gather per-rank FSDP worker frames into the canonical form. For
    /// re-shardable optimizers (see [`RESHARDABLE`]) the result is the
    /// world-agnostic [`OptPayload::Full`] blob — byte-identical to what a
    /// single-process run would export; everything else is kept
    /// [`OptPayload::PerRank`] (world-locked).
    pub fn from_fsdp_frames(
        name: &str,
        frames: Vec<Vec<u8>>,
        metas: &[ParamMeta],
    ) -> Result<CanonicalOptState, String> {
        let payload = match name {
            "galore" => OptPayload::Full(gather_galore(&frames, metas)?),
            "qgalore" => OptPayload::Full(wrap_qgalore(gather_galore(&frames, metas)?)),
            "adamw" => OptPayload::Full(gather_moments(&frames, metas, 2)?),
            "sgdm" => OptPayload::Full(gather_moments(&frames, metas, 1)?),
            _ => OptPayload::PerRank { frames },
        };
        Ok(CanonicalOptState {
            name: name.to_string(),
            payload,
        })
    }

    /// Re-slice the canonical form into per-rank FSDP worker frames for a
    /// target world. Fails loudly — without touching any worker state —
    /// when the state cannot be represented at that world.
    pub fn fsdp_frames(
        &self,
        world: usize,
        metas: &[ParamMeta],
    ) -> Result<Vec<Vec<u8>>, String> {
        match &self.payload {
            OptPayload::PerRank { frames } => {
                if frames.len() == world {
                    Ok(frames.clone())
                } else {
                    Err(format!(
                        "{} optimizer state was captured per-rank at world={} and \
                         cannot be re-sliced to world={world}; resume with --world {} \
                         or train with a re-shardable optimizer ({})",
                        self.name,
                        frames.len(),
                        frames.len(),
                        RESHARDABLE.join(", ")
                    ))
                }
            }
            OptPayload::Full(blob) => match self.name.as_str() {
                "galore" => scatter_galore(blob, world, metas),
                "qgalore" => scatter_galore(&unwrap_qgalore(blob)?, world, metas),
                "adamw" => scatter_moments(blob, world, metas, 2),
                "sgdm" => scatter_moments(blob, world, metas, 1),
                other => {
                    if world == 1 {
                        // A world of one holds the full state: frame it
                        // behind a dormant SVD-stream prefix.
                        let mut frame = dormant_svd_stream();
                        frame.extend_from_slice(blob);
                        Ok(vec![frame])
                    } else {
                        Err(format!(
                            "cannot re-shard {other} optimizer state across \
                             world={world} FSDP ranks; supported: {}",
                            RESHARDABLE.join(", ")
                        ))
                    }
                }
            },
        }
    }

    /// The full-tensor blob for a single-process or DDP (replicated)
    /// import.
    pub fn to_full(&self) -> Result<Vec<u8>, String> {
        match &self.payload {
            OptPayload::Full(blob) => Ok(blob.clone()),
            OptPayload::PerRank { frames } if frames.len() == 1 => {
                // A world-1 FSDP frame holds the full state behind its
                // SVD-stream prefix.
                if frames[0].len() < Pcg64::STATE_BYTES {
                    return Err("truncated per-rank optimizer frame".into());
                }
                Ok(frames[0][Pcg64::STATE_BYTES..].to_vec())
            }
            OptPayload::PerRank { frames } => Err(format!(
                "{} optimizer state is world-locked (captured per-rank at \
                 world={}); resume with --parallel fsdp --world {} or train \
                 with a re-shardable optimizer ({})",
                self.name,
                frames.len(),
                frames.len(),
                RESHARDABLE.join(", ")
            )),
        }
    }
}

/// A never-drawn SVD-stream position for frames of optimizers that hold no
/// RNG (AdamW/SGDM under FSDP never compute subspaces).
fn dormant_svd_stream() -> Vec<u8> {
    let mut out = Vec::with_capacity(Pcg64::STATE_BYTES);
    Pcg64::new(0, 0x6a10).write_state(&mut out);
    out
}

/// Split an FSDP worker frame into its `[svd_rng][optimizer blob]` parts.
fn split_frame(frame: &[u8], rank: usize) -> Result<(&[u8], &[u8]), String> {
    if frame.len() < Pcg64::STATE_BYTES {
        return Err(format!("rank {rank}: truncated FSDP worker frame"));
    }
    Ok(frame.split_at(Pcg64::STATE_BYTES))
}

/// Slice one shard out of a row-major `rows`×`cols` tensor stored as a flat
/// vec. Empty inputs stay empty (lazily-unsized GaLore moments).
fn slice_vec(
    full: &[f32],
    rows: usize,
    cols: usize,
    axis: ShardAxis,
    world: usize,
    rank: usize,
) -> Vec<f32> {
    if full.is_empty() {
        return Vec::new();
    }
    match axis {
        ShardAxis::Rows => {
            let (lo, hi) = shard_bounds(rows, world, rank);
            full[lo * cols..hi * cols].to_vec()
        }
        ShardAxis::Cols => {
            let (lo, hi) = shard_bounds(cols, world, rank);
            let mut out = Vec::with_capacity(rows * (hi - lo));
            for r in 0..rows {
                out.extend_from_slice(&full[r * cols + lo..r * cols + hi]);
            }
            out
        }
    }
}

/// Concatenate per-rank shards (rank order) back into the full row-major
/// tensor. All-empty inputs gather to empty (lazily-unsized moments are
/// unsized on every rank in lockstep).
fn concat_vecs(
    parts: &[Vec<f32>],
    rows: usize,
    cols: usize,
    axis: ShardAxis,
    what: &str,
) -> Result<Vec<f32>, String> {
    let world = parts.len();
    let total: usize = parts.iter().map(|p| p.len()).sum();
    if total == 0 {
        return Ok(Vec::new());
    }
    if total != rows * cols {
        return Err(format!(
            "{what}: per-rank moments sum to {total} elements, expected {rows}x{cols}"
        ));
    }
    match axis {
        ShardAxis::Rows => {
            let mut out = Vec::with_capacity(rows * cols);
            for (rank, p) in parts.iter().enumerate() {
                let (lo, hi) = shard_bounds(rows, world, rank);
                if p.len() != (hi - lo) * cols {
                    return Err(format!(
                        "{what}: rank {rank} holds {} moment elements, expected {}",
                        p.len(),
                        (hi - lo) * cols
                    ));
                }
                out.extend_from_slice(p);
            }
            Ok(out)
        }
        ShardAxis::Cols => {
            let mut out = vec![0f32; rows * cols];
            for (rank, p) in parts.iter().enumerate() {
                let (lo, hi) = shard_bounds(cols, world, rank);
                let w = hi - lo;
                if p.len() != rows * w {
                    return Err(format!(
                        "{what}: rank {rank} holds {} moment elements, expected {}",
                        p.len(),
                        rows * w
                    ));
                }
                for r in 0..rows {
                    out[r * cols + lo..r * cols + hi]
                        .copy_from_slice(&p[r * w..(r + 1) * w]);
                }
            }
            Ok(out)
        }
    }
}

// ---------------------------------------------------------------------------
// GaLore state codec (format defined by `optim::galore::export_state`)
// ---------------------------------------------------------------------------

enum GaloreParamState {
    Full {
        m: Vec<f32>,
        v: Vec<f32>,
    },
    LowRank {
        last_refresh: u64,
        side: u64,
        p_rows: usize,
        p_cols: usize,
        p: Vec<f32>,
        m: Vec<f32>,
        v: Vec<f32>,
    },
}

struct GaloreBlob {
    t: u64,
    refreshes: u64,
    rng: Vec<u8>,
    states: Vec<(usize, GaloreParamState)>,
}

fn parse_galore(bytes: &[u8]) -> Result<GaloreBlob, String> {
    let mut r = Reader::new(bytes);
    let t = r.u64()?;
    let refreshes = r.u64()?;
    let rng = r.bytes(Pcg64::STATE_BYTES)?.to_vec();
    let n = r.u64()? as usize;
    // Every state is at least [idx][tag] = 16 bytes: reject corrupt
    // counts before allocating.
    if n > r.remaining() / 16 {
        return Err(format!("galore state count {n} exceeds blob size"));
    }
    let mut states = Vec::with_capacity(n);
    for _ in 0..n {
        let idx = r.u64()? as usize;
        let tag = r.u64()?;
        let state = if tag == 0 {
            GaloreParamState::Full {
                m: r.f32s()?,
                v: r.f32s()?,
            }
        } else {
            let last_refresh = r.u64()?;
            let side = r.u64()?;
            let p_rows = r.u64()? as usize;
            let p_cols = r.u64()? as usize;
            GaloreParamState::LowRank {
                last_refresh,
                side,
                p_rows,
                p_cols,
                p: r.f32s()?,
                m: r.f32s()?,
                v: r.f32s()?,
            }
        };
        states.push((idx, state));
    }
    Ok(GaloreBlob {
        t,
        refreshes,
        rng,
        states,
    })
}

fn write_galore(b: &GaloreBlob) -> Vec<u8> {
    let mut out = Vec::new();
    push_u64(&mut out, b.t);
    push_u64(&mut out, b.refreshes);
    out.extend_from_slice(&b.rng);
    push_u64(&mut out, b.states.len() as u64);
    for (idx, st) in &b.states {
        push_u64(&mut out, *idx as u64);
        match st {
            GaloreParamState::Full { m, v } => {
                push_u64(&mut out, 0);
                push_f32s(&mut out, m);
                push_f32s(&mut out, v);
            }
            GaloreParamState::LowRank {
                last_refresh,
                side,
                p_rows,
                p_cols,
                p,
                m,
                v,
            } => {
                push_u64(&mut out, 1);
                push_u64(&mut out, *last_refresh);
                push_u64(&mut out, *side);
                push_u64(&mut out, *p_rows as u64);
                push_u64(&mut out, *p_cols as u64);
                push_f32s(&mut out, p);
                push_f32s(&mut out, m);
                push_f32s(&mut out, v);
            }
        }
    }
    out
}

/// Full shape of a low-rank moment tensor: Left projectors (wide params)
/// hold r×n moments, Right projectors (tall params) hold m×r.
fn low_rank_shape(side: u64, p_cols: usize, meta: &ParamMeta) -> (usize, usize) {
    if side == 0 {
        (p_cols, meta.cols)
    } else {
        (meta.rows, p_cols)
    }
}

fn meta_for(metas: &[ParamMeta], idx: usize) -> Result<&ParamMeta, String> {
    metas
        .get(idx)
        .ok_or_else(|| format!("optimizer state names parameter {idx}, model has {}", metas.len()))
}

/// Gather per-rank GaLore worker frames into the single-process blob. The
/// leader's (rank 0's) SVD-stream position becomes the canonical RNG — the
/// same `0x6a10` stream a single-process optimizer draws its sketches
/// from, so a resumed run in ANY mode continues the identical sketch
/// sequence.
fn gather_galore(frames: &[Vec<u8>], metas: &[ParamMeta]) -> Result<Vec<u8>, String> {
    if frames.is_empty() {
        return Err("no worker frames to gather".into());
    }
    let world = frames.len();
    let mut svd_rng = Vec::new();
    let mut blobs = Vec::with_capacity(world);
    for (rank, frame) in frames.iter().enumerate() {
        let (rng, blob) = split_frame(frame, rank)?;
        if rank == 0 {
            svd_rng = rng.to_vec();
        }
        blobs.push(parse_galore(blob).map_err(|e| format!("rank {rank}: {e}"))?);
    }
    let leader = &blobs[0];
    for (rank, b) in blobs.iter().enumerate() {
        if b.t != leader.t || b.states.len() != leader.states.len() {
            return Err(format!(
                "rank {rank} optimizer state out of lockstep with rank 0 \
                 (t {} vs {}, {} vs {} states)",
                b.t,
                leader.t,
                b.states.len(),
                leader.states.len()
            ));
        }
    }
    let mut states = Vec::with_capacity(leader.states.len());
    for (si, (idx, s0)) in leader.states.iter().enumerate() {
        let meta = meta_for(metas, *idx)?;
        let axis = shard_axis(meta.rows, meta.cols);
        // Pull this state's moment shards from every rank, checking the
        // ranks agree on the state's index and kind.
        let mut ms = Vec::with_capacity(world);
        let mut vs = Vec::with_capacity(world);
        for (rank, b) in blobs.iter().enumerate() {
            let (ri, rs) = &b.states[si];
            if ri != idx {
                return Err(format!(
                    "rank {rank}: state {si} is for parameter {ri}, rank 0 has {idx}"
                ));
            }
            match (s0, rs) {
                (GaloreParamState::Full { .. }, GaloreParamState::Full { m, v }) => {
                    ms.push(m.clone());
                    vs.push(v.clone());
                }
                (
                    GaloreParamState::LowRank { .. },
                    GaloreParamState::LowRank { m, v, .. },
                ) => {
                    ms.push(m.clone());
                    vs.push(v.clone());
                }
                _ => {
                    return Err(format!(
                        "rank {rank}: parameter {idx} state kind differs from rank 0"
                    ))
                }
            }
        }
        let gathered = match s0 {
            GaloreParamState::Full { .. } => GaloreParamState::Full {
                m: concat_vecs(&ms, meta.rows, meta.cols, axis, &meta.name)?,
                v: concat_vecs(&vs, meta.rows, meta.cols, axis, &meta.name)?,
            },
            GaloreParamState::LowRank {
                last_refresh,
                side,
                p_rows,
                p_cols,
                p,
                ..
            } => {
                // P is replicated (it spans the un-sharded dimension), so
                // rank 0's copy IS the full projector.
                let (lm, ln) = low_rank_shape(*side, *p_cols, meta);
                GaloreParamState::LowRank {
                    last_refresh: *last_refresh,
                    side: *side,
                    p_rows: *p_rows,
                    p_cols: *p_cols,
                    p: p.clone(),
                    m: concat_vecs(&ms, lm, ln, axis, &meta.name)?,
                    v: concat_vecs(&vs, lm, ln, axis, &meta.name)?,
                }
            }
        };
        states.push((*idx, gathered));
    }
    Ok(write_galore(&GaloreBlob {
        t: leader.t,
        refreshes: leader.refreshes,
        rng: svd_rng,
        states,
    }))
}

/// Re-slice a single-process GaLore blob into per-rank FSDP worker frames.
/// Every rank's frame carries the canonical RNG position; only the leader
/// ever draws from it, continuing the exact stream the source run (single,
/// DDP, or FSDP at any world) would have used.
fn scatter_galore(
    blob: &[u8],
    world: usize,
    metas: &[ParamMeta],
) -> Result<Vec<Vec<u8>>, String> {
    let b = parse_galore(blob)?;
    let mut frames = Vec::with_capacity(world);
    for rank in 0..world {
        let mut states = Vec::with_capacity(b.states.len());
        for (idx, st) in &b.states {
            let meta = meta_for(metas, *idx)?;
            let axis = shard_axis(meta.rows, meta.cols);
            let sliced = match st {
                GaloreParamState::Full { m, v } => {
                    for (name, mom) in [("m", m), ("v", v)] {
                        if !mom.is_empty() && mom.len() != meta.rows * meta.cols {
                            return Err(format!(
                                "{}: canonical {name} moment has {} elements, expected {}x{}",
                                meta.name,
                                mom.len(),
                                meta.rows,
                                meta.cols
                            ));
                        }
                    }
                    GaloreParamState::Full {
                        m: slice_vec(m, meta.rows, meta.cols, axis, world, rank),
                        v: slice_vec(v, meta.rows, meta.cols, axis, world, rank),
                    }
                }
                GaloreParamState::LowRank {
                    last_refresh,
                    side,
                    p_rows,
                    p_cols,
                    p,
                    m,
                    v,
                } => {
                    let (lm, ln) = low_rank_shape(*side, *p_cols, meta);
                    for (name, mom) in [("m", m), ("v", v)] {
                        if !mom.is_empty() && mom.len() != lm * ln {
                            return Err(format!(
                                "{}: canonical low-rank {name} moment has {} elements, \
                                 expected {lm}x{ln}",
                                meta.name,
                                mom.len()
                            ));
                        }
                    }
                    GaloreParamState::LowRank {
                        last_refresh: *last_refresh,
                        side: *side,
                        p_rows: *p_rows,
                        p_cols: *p_cols,
                        p: p.clone(),
                        m: slice_vec(m, lm, ln, axis, world, rank),
                        v: slice_vec(v, lm, ln, axis, world, rank),
                    }
                }
            };
            states.push((*idx, sliced));
        }
        let mut frame = b.rng.clone();
        frame.extend_from_slice(&write_galore(&GaloreBlob {
            t: b.t,
            refreshes: b.refreshes,
            rng: b.rng.clone(),
            states,
        }));
        frames.push(frame);
    }
    Ok(frames)
}

// ---------------------------------------------------------------------------
// Q-GaLore framing (format defined by `optim::qgalore::export_state`)
// ---------------------------------------------------------------------------

/// Wrap a GaLore blob in Q-GaLore's framing with an empty lazy-gate: under
/// FSDP the gate is inert (the coordinator owns refreshes), so gathered
/// state carries no gate history — a single/DDP resume re-seeds the gate
/// from its first post-resume refresh probe.
fn wrap_qgalore(inner: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::new();
    push_u64(&mut out, inner.len() as u64);
    out.extend_from_slice(&inner);
    push_u64(&mut out, 0); // refreshes skipped by the gate
    push_u64(&mut out, 0); // refreshes taken
    push_u64(&mut out, 0); // no per-parameter probe directions
    out
}

/// Extract the inner GaLore blob from Q-GaLore framing (the gate state is
/// dropped: it is inert under FSDP).
fn unwrap_qgalore(blob: &[u8]) -> Result<Vec<u8>, String> {
    let mut r = Reader::new(blob);
    let len = r.u64()? as usize;
    Ok(r.bytes(len)?.to_vec())
}

// ---------------------------------------------------------------------------
// Plain moment-map codec (AdamW: 2 moment tensors; SGDM: 1) — format
// defined by `optim::adamw::export_state` / `optim::sgdm::export_state`:
// `[t u64][n u64]` then per state `[idx u64]` + nmoments length-framed f32
// vectors.
// ---------------------------------------------------------------------------

type MomentStates = Vec<(usize, Vec<Vec<f32>>)>;

fn parse_moments(bytes: &[u8], nmoments: usize) -> Result<(u64, MomentStates), String> {
    let mut r = Reader::new(bytes);
    let t = r.u64()?;
    let n = r.u64()? as usize;
    // Every state is at least [idx] + nmoments length headers: reject
    // corrupt counts before allocating.
    if n > r.remaining() / (8 * (1 + nmoments)) {
        return Err(format!("optimizer state count {n} exceeds blob size"));
    }
    let mut states = Vec::with_capacity(n);
    for _ in 0..n {
        let idx = r.u64()? as usize;
        let mut moments = Vec::with_capacity(nmoments);
        for _ in 0..nmoments {
            moments.push(r.f32s()?);
        }
        states.push((idx, moments));
    }
    Ok((t, states))
}

fn write_moments(t: u64, states: &MomentStates) -> Vec<u8> {
    let mut out = Vec::new();
    push_u64(&mut out, t);
    push_u64(&mut out, states.len() as u64);
    for (idx, moments) in states {
        push_u64(&mut out, *idx as u64);
        for m in moments {
            push_f32s(&mut out, m);
        }
    }
    out
}

fn gather_moments(
    frames: &[Vec<u8>],
    metas: &[ParamMeta],
    nmoments: usize,
) -> Result<Vec<u8>, String> {
    if frames.is_empty() {
        return Err("no worker frames to gather".into());
    }
    let world = frames.len();
    let mut per_rank = Vec::with_capacity(world);
    for (rank, frame) in frames.iter().enumerate() {
        let (_rng, blob) = split_frame(frame, rank)?;
        per_rank.push(parse_moments(blob, nmoments).map_err(|e| format!("rank {rank}: {e}"))?);
    }
    let (t, leader) = &per_rank[0];
    for (rank, (rt, rs)) in per_rank.iter().enumerate() {
        if rt != t || rs.len() != leader.len() {
            return Err(format!(
                "rank {rank} optimizer state out of lockstep with rank 0"
            ));
        }
    }
    let mut states = Vec::with_capacity(leader.len());
    for (si, (idx, _)) in leader.iter().enumerate() {
        let meta = meta_for(metas, *idx)?;
        let axis = shard_axis(meta.rows, meta.cols);
        let mut moments = Vec::with_capacity(nmoments);
        for k in 0..nmoments {
            let mut parts = Vec::with_capacity(world);
            for (rank, (_, rs)) in per_rank.iter().enumerate() {
                let (ri, rm) = &rs[si];
                if ri != idx {
                    return Err(format!(
                        "rank {rank}: state {si} is for parameter {ri}, rank 0 has {idx}"
                    ));
                }
                parts.push(rm[k].clone());
            }
            moments.push(concat_vecs(&parts, meta.rows, meta.cols, axis, &meta.name)?);
        }
        states.push((*idx, moments));
    }
    Ok(write_moments(*t, &states))
}

fn scatter_moments(
    blob: &[u8],
    world: usize,
    metas: &[ParamMeta],
    nmoments: usize,
) -> Result<Vec<Vec<u8>>, String> {
    let (t, states) = parse_moments(blob, nmoments)?;
    let mut frames = Vec::with_capacity(world);
    for rank in 0..world {
        let mut sliced = Vec::with_capacity(states.len());
        for (idx, moments) in &states {
            let meta = meta_for(metas, *idx)?;
            let axis = shard_axis(meta.rows, meta.cols);
            let mut shards = Vec::with_capacity(nmoments);
            for m in moments {
                if m.len() != meta.rows * meta.cols {
                    return Err(format!(
                        "{}: canonical moment has {} elements, expected {}x{}",
                        meta.name,
                        m.len(),
                        meta.rows,
                        meta.cols
                    ));
                }
                shards.push(slice_vec(m, meta.rows, meta.cols, axis, world, rank));
            }
            sliced.push((*idx, shards));
        }
        let mut frame = dormant_svd_stream();
        frame.extend_from_slice(&write_moments(t, &sliced));
        frames.push(frame);
    }
    Ok(frames)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metas(shapes: &[(usize, usize)]) -> Vec<ParamMeta> {
        shapes
            .iter()
            .enumerate()
            .map(|(i, &(r, c))| ParamMeta {
                name: format!("p{i}"),
                rows: r,
                cols: c,
            })
            .collect()
    }

    #[test]
    fn encode_decode_roundtrip_both_flavors() {
        let full = CanonicalOptState::full("galore", vec![1, 2, 3]);
        assert_eq!(CanonicalOptState::decode(&full.encode()).unwrap(), full);
        let per_rank = CanonicalOptState {
            name: "adam8bit".into(),
            payload: OptPayload::PerRank {
                frames: vec![vec![9; 40], vec![8; 33]],
            },
        };
        assert_eq!(
            CanonicalOptState::decode(&per_rank.encode()).unwrap(),
            per_rank
        );
    }

    #[test]
    fn sniff_distinguishes_legacy_blobs() {
        assert!(CanonicalOptState::sniff(
            &CanonicalOptState::full("adamw", vec![]).encode()
        ));
        // Legacy blobs start with a small little-endian counter (a step or
        // a world size), never the magic.
        assert!(!CanonicalOptState::sniff(&7u64.to_le_bytes()));
        assert!(!CanonicalOptState::sniff(b"GAL"));
        assert!(!CanonicalOptState::sniff(&[]));
    }

    #[test]
    fn decode_rejects_truncation_and_garbage() {
        let blob = CanonicalOptState::full("galore", vec![0; 64]).encode();
        assert!(CanonicalOptState::decode(&blob[..blob.len() - 9]).is_err());
        let err = CanonicalOptState::decode(b"not a canonical blob....").unwrap_err();
        assert!(err.contains("GAL2OPT"), "unhelpful error: {err}");
    }

    #[test]
    fn name_mismatch_is_loud() {
        let c = CanonicalOptState::full("galore", vec![]);
        let err = c.expect_name("adamw").unwrap_err();
        assert!(err.contains("galore") && err.contains("adamw"));
    }

    #[test]
    fn slice_concat_roundtrip_all_axes_and_worlds() {
        for (rows, cols) in [(3usize, 8usize), (8, 3), (1, 5), (4, 4)] {
            let full: Vec<f32> = (0..rows * cols).map(|i| i as f32).collect();
            let axis = shard_axis(rows, cols);
            for world in [1usize, 2, 3, 4, 5, 7] {
                let parts: Vec<Vec<f32>> = (0..world)
                    .map(|r| slice_vec(&full, rows, cols, axis, world, r))
                    .collect();
                let back = concat_vecs(&parts, rows, cols, axis, "t").unwrap();
                assert_eq!(back, full, "{rows}x{cols} world {world}");
            }
        }
    }

    #[test]
    fn empty_moments_stay_empty_through_gather_and_scatter() {
        // Lazily-unsized GaLore moments are empty on every rank in
        // lockstep; the canonical form keeps them unsized.
        let parts = vec![Vec::new(), Vec::new(), Vec::new()];
        assert_eq!(
            concat_vecs(&parts, 4, 6, ShardAxis::Cols, "t").unwrap(),
            Vec::<f32>::new()
        );
        assert_eq!(
            slice_vec(&[], 4, 6, ShardAxis::Cols, 3, 1),
            Vec::<f32>::new()
        );
    }

    #[test]
    fn concat_rejects_inconsistent_shards() {
        let parts = vec![vec![0.0; 5], vec![0.0; 5]];
        let err = concat_vecs(&parts, 2, 4, ShardAxis::Cols, "p0").unwrap_err();
        assert!(err.contains("expected"), "unhelpful error: {err}");
    }

    #[test]
    fn moment_blob_scatter_gather_is_identity() {
        // gather(scatter(blob)) == blob for the AdamW codec at several
        // worlds, including worlds larger than the narrow (1, 3) bias —
        // which leaves some ranks with empty shards.
        let shapes = [(4usize, 6usize), (6, 4), (1, 3)];
        let ms = metas(&shapes);
        let states: MomentStates = shapes
            .iter()
            .enumerate()
            .map(|(i, &(r, c))| {
                let m: Vec<f32> = (0..r * c).map(|k| (i * 100 + k) as f32).collect();
                let v: Vec<f32> = (0..r * c).map(|k| (i * 100 + k) as f32 * 0.5).collect();
                (i, vec![m, v])
            })
            .collect();
        let blob = write_moments(7, &states);
        for world in [1usize, 2, 3, 4, 5] {
            let frames = scatter_moments(&blob, world, &ms, 2).unwrap();
            assert_eq!(frames.len(), world);
            let back = gather_moments(&frames, &ms, 2).unwrap();
            assert_eq!(back, blob, "world {world}: scatter/gather not identity");
        }
    }

    #[test]
    fn galore_blob_scatter_gather_is_identity() {
        // Same identity for the GaLore codec: a wide low-rank state (Left
        // projector, r×n moments), a tall one (Right, m×r), a full-rank
        // fallback, and a lazily-unsized low-rank state.
        let shapes = [(4usize, 10usize), (10, 4), (1, 6), (5, 5)];
        let ms = metas(&shapes);
        let r = 2usize;
        let states = vec![
            (
                0,
                GaloreParamState::LowRank {
                    last_refresh: 3,
                    side: 0,
                    p_rows: 4,
                    p_cols: r,
                    p: (0..4 * r).map(|k| k as f32).collect(),
                    m: (0..r * 10).map(|k| k as f32 + 0.25).collect(),
                    v: (0..r * 10).map(|k| k as f32 + 0.5).collect(),
                },
            ),
            (
                1,
                GaloreParamState::LowRank {
                    last_refresh: 3,
                    side: 1,
                    p_rows: 4,
                    p_cols: r,
                    p: (0..4 * r).map(|k| k as f32).collect(),
                    m: (0..10 * r).map(|k| k as f32 - 0.25).collect(),
                    v: (0..10 * r).map(|k| k as f32 - 0.5).collect(),
                },
            ),
            (
                2,
                GaloreParamState::Full {
                    m: (0..6).map(|k| k as f32).collect(),
                    v: (0..6).map(|k| k as f32 * 2.0).collect(),
                },
            ),
            (
                3,
                GaloreParamState::LowRank {
                    last_refresh: 0,
                    side: 0,
                    p_rows: 5,
                    p_cols: r,
                    p: (0..5 * r).map(|k| k as f32).collect(),
                    m: Vec::new(), // lazily unsized: preset but never stepped
                    v: Vec::new(),
                },
            ),
        ];
        let mut rng_bytes = Vec::new();
        Pcg64::new(11, 0x6a10).write_state(&mut rng_bytes);
        let blob = write_galore(&GaloreBlob {
            t: 9,
            refreshes: 4,
            rng: rng_bytes,
            states,
        });
        for world in [1usize, 2, 3, 4, 5] {
            let frames = scatter_galore(&blob, world, &ms).unwrap();
            assert_eq!(frames.len(), world);
            let back = gather_galore(&frames, &ms).unwrap();
            assert_eq!(back, blob, "world {world}: scatter/gather not identity");
        }
    }

    #[test]
    fn corrupt_counts_error_instead_of_aborting() {
        // Bit-flipped counts must yield Err, not a capacity-overflow
        // abort that bypasses the loud-failure contract.
        let mut blob = Vec::new();
        blob.extend_from_slice(MAGIC);
        push_u64(&mut blob, 6);
        blob.extend_from_slice(b"galore");
        push_u64(&mut blob, FLAVOR_PER_RANK);
        push_u64(&mut blob, u64::MAX); // insane frame count
        assert!(CanonicalOptState::decode(&blob).is_err());

        let mut g = Vec::new();
        push_u64(&mut g, 0); // t
        push_u64(&mut g, 0); // refreshes
        Pcg64::new(0, 0).write_state(&mut g);
        push_u64(&mut g, u64::MAX); // insane state count
        assert!(parse_galore(&g).is_err());

        let mut m = Vec::new();
        push_u64(&mut m, 0); // t
        push_u64(&mut m, u64::MAX); // insane state count
        assert!(parse_moments(&m, 2).is_err());
    }

    #[test]
    fn codec_conversion_bridges_raw_and_framed_qgalore_layouts() {
        // The "qgalore" name covers two layouts (OptimizerSpec::state_codec):
        // a concrete GaLore exporting the raw layout must still produce a
        // framed canonical blob, and imports convert back per target codec.
        let raw = vec![7u8; 40];
        let c = CanonicalOptState::from_full("qgalore", "galore", raw.clone());
        assert_eq!(c.to_full_for("galore").unwrap(), raw, "raw → framed → raw");
        assert_eq!(
            c.to_full_for("qgalore").unwrap(),
            wrap_qgalore(raw.clone()),
            "framed view keeps the canonical layout"
        );
        // A true QGaLore blob passes through unchanged for its own codec.
        let framed = wrap_qgalore(raw.clone());
        let c = CanonicalOptState::from_full("qgalore", "qgalore", framed.clone());
        assert_eq!(c.to_full_for("qgalore").unwrap(), framed);
        assert_eq!(c.to_full_for("galore").unwrap(), raw);
        // Non-family names are untouched by codec conversion.
        let c = CanonicalOptState::from_full("adamw", "adamw", raw.clone());
        assert_eq!(c.to_full_for("adamw").unwrap(), raw);
    }

    #[test]
    fn qgalore_framing_roundtrips() {
        let inner = vec![5u8; 24];
        let wrapped = wrap_qgalore(inner.clone());
        assert_eq!(unwrap_qgalore(&wrapped).unwrap(), inner);
        assert!(unwrap_qgalore(&wrapped[..10]).is_err());
    }

    #[test]
    fn per_rank_world_mismatch_errors_are_actionable() {
        let c = CanonicalOptState {
            name: "adam8bit".into(),
            payload: OptPayload::PerRank {
                frames: vec![vec![0; 40]; 2],
            },
        };
        let err = c.fsdp_frames(4, &[]).unwrap_err();
        assert!(
            err.contains("world=2") && err.contains("adam8bit"),
            "unhelpful error: {err}"
        );
        let err = c.to_full().unwrap_err();
        assert!(err.contains("world-locked"), "unhelpful error: {err}");
        // Same-world passthrough still works.
        assert_eq!(c.fsdp_frames(2, &[]).unwrap().len(), 2);
    }

    #[test]
    fn non_reshardable_full_state_only_fits_world_one() {
        let c = CanonicalOptState::full("adafactor", vec![3; 50]);
        let frames = c.fsdp_frames(1, &[]).unwrap();
        assert_eq!(frames.len(), 1);
        assert_eq!(&frames[0][Pcg64::STATE_BYTES..], &[3u8; 50][..]);
        let err = c.fsdp_frames(2, &[]).unwrap_err();
        assert!(err.contains("adafactor"), "unhelpful error: {err}");
    }
}
