//! The canonical (world-agnostic) optimizer-state form.
//!
//! Execution modes serialize optimizer state differently: a single-process
//! run exports one full-tensor blob, a DDP cluster exports rank 0's
//! replica, and an FSDP cluster exports one *shard-local* frame per rank.
//! Before this module, FSDP resume therefore hard-required the same world
//! size — an elastic restart (resume at a different `--world`, or switch
//! between `--parallel` modes) was impossible.
//!
//! [`CanonicalOptState`] fixes that by normalizing everything to one form
//! at checkpoint time:
//!
//! * **Full** — the single-process blob: full-tensor moments, the
//!   optimizer's RNG stream position, Q-GaLore's lazy-gate state. FSDP
//!   exports are *gathered* into this form (per-rank moment shards are
//!   concatenated along each parameter's shard axis; the leader's
//!   SVD-stream position becomes the canonical stream), and on import the
//!   form is *re-sliced* for any target world — including world 1,
//!   non-power-of-two worlds, and worlds that leave some ranks with empty
//!   shards.
//! * **PerRank** — the escape hatch for optimizers whose state cannot be
//!   re-sliced bit-exactly (block-quantized Adam8bit moments, Adafactor's
//!   factored accumulators): the raw per-rank frames ride along
//!   world-locked, and any cross-world import fails loudly instead of
//!   silently resetting moments.
//!
//! The gather/scatter pair is the identity on the canonical form, and for
//! the re-shardable optimizers (AdamW, SGDM, GaLore, Q-GaLore) the
//! canonical bytes are *identical* no matter which mode or world exported
//! them — `tests/resharding.rs` pins both properties.
//!
//! **Quantized canonical state (checkpoint v5).** Optimizers whose stored
//! representation is not plain f32 get a third, *typed* flavor:
//!
//! * **Quantized** — the optimizer's stored representation carried as
//!   [`CanonicalTensor`]s (f32 vectors or exact codes+block-scales via the
//!   `quant` codec). Adam8bit's block-quantized moments live here: an FSDP
//!   export whose shard boundaries all land on 256-element quantization
//!   blocks gathers EXACTLY into the same bytes a single-process run would
//!   export, and re-slices exactly for any block-aligned target world.
//!   Adafactor's factored accumulators ride as f32 tensors from
//!   single/DDP exports.
//!
//! Geometries that cannot be re-sliced exactly (misaligned quant blocks,
//! factored cross-statistics, a different per-rank world) stay available
//! behind an **explicit, loud opt-in** — [`ImportOpts::requantize`]
//! (`--resume-requantize`): moments are dequantized, re-sliced, and
//! re-quantized (adam8bit), or the factored cross-statistic is merged /
//! replicated (adafactor). Without the opt-in those imports FAIL with an
//! actionable error; they never silently approximate.

use crate::dist::{shard_axis, shard_bounds, ParamMeta, ShardAxis};
use crate::optim::ser::{push_f32s, push_u64, Reader, STATE_MAGIC2};
use crate::quant::{Quantized8, StoredTensor, BLOCK};
use crate::util::rng::Pcg64;

/// Header identifying a canonical optimizer-state blob (v3+ checkpoints).
/// Legacy (v2) payloads — raw single-process blobs or FSDP `[world]`-framed
/// blobs — never start with these bytes (they begin with a small
/// little-endian counter), so [`CanonicalOptState::sniff`] is unambiguous.
pub const MAGIC: &[u8; 8] = b"GAL2OPT\x01";

const FLAVOR_FULL: u64 = 0;
const FLAVOR_PER_RANK: u64 = 1;
const FLAVOR_QUANTIZED: u64 = 2;

/// Optimizer names whose state the canonical form can re-slice for an
/// arbitrary FSDP world, bitwise. (`adam8bit` additionally re-slices
/// bitwise for *block-aligned* worlds, and every optimizer re-slices
/// approximately behind [`ImportOpts::requantize`].)
pub const RESHARDABLE: &[&str] = &["adamw", "sgdm", "galore", "qgalore"];

/// Resume-time import policy, plumbed from `--resume-requantize` /
/// `[train] resume_requantize` through [`crate::train::TrainEngine`].
///
/// With `requantize: false` (the default) every import is either bitwise
/// exact or a loud error. With `requantize: true` the lossy conversions
/// are allowed — and announced on stderr — for state that cannot be
/// re-sliced exactly: re-blocking quantized moments across misaligned
/// shard boundaries, and merging/replicating Adafactor's factored
/// cross-statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct ImportOpts {
    pub requantize: bool,
}

impl ImportOpts {
    /// The opt-in policy (`--resume-requantize`).
    pub fn requantize() -> ImportOpts {
        ImportOpts { requantize: true }
    }
}

/// One stored tensor inside the [`OptPayload::Quantized`] flavor: either a
/// plain f32 vector or exact block-quantized codes + scales (the `quant`
/// codec's dynamic-8-bit layout, which is what Adam8bit stores).
#[derive(Clone, Debug, PartialEq)]
pub enum CanonicalTensor {
    F32(Vec<f32>),
    Q8(Quantized8),
}

const CT_F32: u8 = 0;
const CT_Q8: u8 = 1;

impl CanonicalTensor {
    pub fn len(&self) -> usize {
        match self {
            CanonicalTensor::F32(xs) => xs.len(),
            CanonicalTensor::Q8(q) => q.len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Dequantized values (f32 passes through untouched).
    pub fn values(&self) -> Vec<f32> {
        match self {
            CanonicalTensor::F32(xs) => xs.clone(),
            CanonicalTensor::Q8(q) => q.dequantize(),
        }
    }

    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            CanonicalTensor::F32(xs) => {
                out.push(CT_F32);
                push_f32s(out, xs);
            }
            CanonicalTensor::Q8(q) => {
                out.push(CT_Q8);
                q.encode(out);
            }
        }
    }

    fn decode(r: &mut Reader) -> Result<CanonicalTensor, String> {
        match r.bytes(1)?[0] {
            CT_F32 => Ok(CanonicalTensor::F32(r.f32s()?)),
            CT_Q8 => Ok(CanonicalTensor::Q8(Quantized8::decode(r)?)),
            other => Err(format!("canonical tensor: unknown storage tag {other}")),
        }
    }
}

/// Per-parameter states of the [`OptPayload::Quantized`] flavor, in
/// ascending parameter-index order (matching the optimizers' BTreeMap
/// iteration, so re-serialization is byte-stable).
pub type QuantStates = Vec<(usize, Vec<CanonicalTensor>)>;

/// The payload of a canonical optimizer state.
#[derive(Clone, Debug, PartialEq)]
pub enum OptPayload {
    /// World-agnostic full-tensor blob in the single-process format.
    Full(Vec<u8>),
    /// World-locked raw per-rank frames (state whose exact gather is not
    /// representable world-agnostically: misaligned quantized moments,
    /// factored accumulators under FSDP).
    PerRank { frames: Vec<Vec<u8>> },
    /// Typed stored-representation states (v5): full-tensor
    /// [`CanonicalTensor`]s per parameter plus the optimizer's step
    /// counter. Adam8bit: `[m, v]` quantized moments; Adafactor:
    /// `[row, col]` f32 accumulators.
    Quantized { t: u64, states: QuantStates },
}

/// A checkpoint's optimizer state, normalized away from the execution mode
/// and world size that produced it.
#[derive(Clone, Debug, PartialEq)]
pub struct CanonicalOptState {
    /// Optimizer name (`OptimizerSpec::name`): imports cross-check it so a
    /// galore checkpoint can never silently feed adamw moments.
    pub name: String,
    pub payload: OptPayload,
}

impl CanonicalOptState {
    /// Whether `bytes` carry the canonical header (v3) — as opposed to a
    /// legacy (v2) mode-specific blob.
    pub fn sniff(bytes: &[u8]) -> bool {
        bytes.len() >= MAGIC.len() && &bytes[..MAGIC.len()] == MAGIC
    }

    /// Wrap a single-process/DDP full-tensor blob already in the
    /// canonical layout for `name`. Prefer [`CanonicalOptState::from_full`],
    /// which converts from the exporting optimizer's layout.
    pub fn full(name: &str, blob: Vec<u8>) -> CanonicalOptState {
        CanonicalOptState {
            name: name.to_string(),
            payload: OptPayload::Full(blob),
        }
    }

    /// Wrap a full-tensor blob serialized in `codec` layout (see
    /// [`OptimizerSpec::state_codec`]) into the canonical layout for
    /// `name`: "qgalore"-named state is canonically Q-GaLore-framed even
    /// when the exporting optimizer was a concrete `GaLore` holding the
    /// raw layout (the quantized-projector GaLore spec, whose name is
    /// also "qgalore"), and the "adam8bit"/"adafactor" codecs parse into
    /// the typed [`OptPayload::Quantized`] flavor (legacy dequantized
    /// adam8bit blobs stay opaque [`OptPayload::Full`], bit-preserving).
    ///
    /// [`OptimizerSpec::state_codec`]: crate::optim::OptimizerSpec::state_codec
    pub fn from_full(name: &str, codec: &str, blob: Vec<u8>) -> Result<CanonicalOptState, String> {
        let payload = match codec {
            "adam8bit" if sniff_magic2(&blob) => {
                let (t, states) = parse_adam8bit(&blob)?;
                OptPayload::Quantized { t, states }
            }
            "adafactor" => {
                let (t, states) = parse_adafactor(&blob)?;
                OptPayload::Quantized { t, states }
            }
            "galore" if name == "qgalore" => OptPayload::Full(wrap_qgalore(blob)),
            _ => OptPayload::Full(blob),
        };
        Ok(CanonicalOptState {
            name: name.to_string(),
            payload,
        })
    }

    /// The full-tensor blob converted to the importing optimizer's
    /// `codec` layout (the lazy-gate state is dropped when a framed
    /// "qgalore" blob feeds a concrete `GaLore`, mirroring FSDP's inert
    /// gate). `metas` + `opts` feed the [`CanonicalOptState::to_full`]
    /// conversion paths.
    pub fn to_full_for(
        &self,
        codec: &str,
        metas: &[ParamMeta],
        opts: ImportOpts,
    ) -> Result<Vec<u8>, String> {
        let blob = self.to_full(metas, opts)?;
        if self.name == "qgalore" && codec == "galore" {
            unwrap_qgalore(&blob)
        } else {
            Ok(blob)
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        push_u64(&mut out, self.name.len() as u64);
        out.extend_from_slice(self.name.as_bytes());
        match &self.payload {
            OptPayload::Full(blob) => {
                push_u64(&mut out, FLAVOR_FULL);
                push_u64(&mut out, blob.len() as u64);
                out.extend_from_slice(blob);
            }
            OptPayload::PerRank { frames } => {
                push_u64(&mut out, FLAVOR_PER_RANK);
                push_u64(&mut out, frames.len() as u64);
                for f in frames {
                    push_u64(&mut out, f.len() as u64);
                    out.extend_from_slice(f);
                }
            }
            OptPayload::Quantized { t, states } => {
                push_u64(&mut out, FLAVOR_QUANTIZED);
                push_u64(&mut out, *t);
                push_u64(&mut out, states.len() as u64);
                for (idx, tensors) in states {
                    push_u64(&mut out, *idx as u64);
                    push_u64(&mut out, tensors.len() as u64);
                    for tensor in tensors {
                        tensor.encode(&mut out);
                    }
                }
            }
        }
        out
    }

    pub fn decode(bytes: &[u8]) -> Result<CanonicalOptState, String> {
        if !Self::sniff(bytes) {
            return Err(
                "not a canonical optimizer-state blob (missing GAL2OPT header); \
                 legacy (v2) checkpoints store mode-specific state instead"
                    .into(),
            );
        }
        let mut r = Reader::new(&bytes[MAGIC.len()..]);
        let name_len = r.u64()? as usize;
        let name = String::from_utf8(r.bytes(name_len)?.to_vec())
            .map_err(|_| "canonical state: optimizer name is not utf-8".to_string())?;
        let payload = match r.u64()? {
            FLAVOR_FULL => {
                let len = r.u64()? as usize;
                OptPayload::Full(r.bytes(len)?.to_vec())
            }
            FLAVOR_PER_RANK => {
                let world = r.u64()? as usize;
                // Each frame needs at least its 8-byte length header:
                // bound the count before allocating, so a corrupt u64
                // yields an Err instead of a capacity-overflow abort.
                if world > r.remaining() / 8 {
                    return Err(format!(
                        "canonical state: per-rank frame count {world} exceeds blob size"
                    ));
                }
                let mut frames = Vec::with_capacity(world);
                for _ in 0..world {
                    let len = r.u64()? as usize;
                    frames.push(r.bytes(len)?.to_vec());
                }
                OptPayload::PerRank { frames }
            }
            FLAVOR_QUANTIZED => {
                let t = r.u64()?;
                let n = r.u64()? as usize;
                // Each state is at least [idx][ntensors]: bound before
                // allocating.
                if n > r.remaining() / 16 {
                    return Err(format!(
                        "canonical state: quantized state count {n} exceeds blob size"
                    ));
                }
                let mut states = Vec::with_capacity(n);
                for _ in 0..n {
                    let idx = r.u64()? as usize;
                    let k = r.u64()? as usize;
                    // Each tensor is at least a tag + one u64 header.
                    if k > r.remaining() / 9 {
                        return Err(format!(
                            "canonical state: tensor count {k} exceeds blob size"
                        ));
                    }
                    let mut tensors = Vec::with_capacity(k);
                    for _ in 0..k {
                        tensors.push(CanonicalTensor::decode(&mut r)?);
                    }
                    states.push((idx, tensors));
                }
                OptPayload::Quantized { t, states }
            }
            other => return Err(format!("canonical state: unknown flavor {other}")),
        };
        Ok(CanonicalOptState { name, payload })
    }

    /// Fail unless the checkpoint's optimizer matches the running one.
    pub fn expect_name(&self, want: &str) -> Result<(), String> {
        if self.name == want {
            Ok(())
        } else {
            Err(format!(
                "checkpoint holds {} optimizer state but this run uses {want}; \
                 restart with --optimizer {} (or retrain)",
                self.name, self.name
            ))
        }
    }

    /// Gather per-rank FSDP worker frames into the canonical form. For
    /// re-shardable optimizers (see [`RESHARDABLE`]) the result is the
    /// world-agnostic [`OptPayload::Full`] blob — byte-identical to what a
    /// single-process run would export. Adam8bit gathers into the typed
    /// [`OptPayload::Quantized`] flavor when every shard boundary lands on
    /// a quantization-block boundary (then also byte-identical to the
    /// single-process export); everything else — misaligned adam8bit,
    /// adafactor's rank-local factored statistics — is kept
    /// [`OptPayload::PerRank`] (lossless, world-locked without the
    /// [`ImportOpts::requantize`] opt-in).
    pub fn from_fsdp_frames(
        name: &str,
        frames: Vec<Vec<u8>>,
        metas: &[ParamMeta],
    ) -> Result<CanonicalOptState, String> {
        let payload = match name {
            "galore" => OptPayload::Full(gather_galore(&frames, metas)?),
            "qgalore" => OptPayload::Full(wrap_qgalore(gather_galore(&frames, metas)?)),
            "adamw" => OptPayload::Full(gather_moments(&frames, metas, 2)?),
            "sgdm" => OptPayload::Full(gather_moments(&frames, metas, 1)?),
            "adam8bit" => gather_adam8bit(frames, metas)?,
            _ => OptPayload::PerRank { frames },
        };
        Ok(CanonicalOptState {
            name: name.to_string(),
            payload,
        })
    }

    /// Re-slice the canonical form into per-rank FSDP worker frames for a
    /// target world. Fails loudly — without touching any worker state —
    /// when the state cannot be represented exactly at that world and the
    /// lossy conversion was not opted into ([`ImportOpts::requantize`]).
    pub fn fsdp_frames(
        &self,
        world: usize,
        metas: &[ParamMeta],
        opts: ImportOpts,
    ) -> Result<Vec<Vec<u8>>, String> {
        match &self.payload {
            OptPayload::PerRank { frames } => {
                if frames.len() == world {
                    Ok(frames.clone())
                } else {
                    match self.name.as_str() {
                        "adam8bit" if opts.requantize => {
                            let (t, states) = merge_adam8bit_frames(frames, metas)?;
                            scatter_adam8bit(t, &states, world, metas, opts)
                        }
                        "adafactor" if opts.requantize => {
                            let (t, states) = merge_adafactor_frames(frames, metas)?;
                            scatter_adafactor(t, &states, world, metas, opts)
                        }
                        "adam8bit" | "adafactor" => Err(format!(
                            "{} optimizer state was captured per-rank at world={} and \
                             cannot be re-sliced to world={world} exactly; resume with \
                             --world {} for a bitwise continuation, or pass \
                             --resume-requantize to accept an approximate re-slice",
                            self.name,
                            frames.len(),
                            frames.len(),
                        )),
                        _ => Err(format!(
                            "{} optimizer state was captured per-rank at world={} and \
                             cannot be re-sliced to world={world}; resume with --world {} \
                             or train with a re-shardable optimizer ({})",
                            self.name,
                            frames.len(),
                            frames.len(),
                            RESHARDABLE.join(", ")
                        )),
                    }
                }
            }
            OptPayload::Quantized { t, states } => match self.name.as_str() {
                "adam8bit" => scatter_adam8bit(*t, states, world, metas, opts),
                "adafactor" => scatter_adafactor(*t, states, world, metas, opts),
                other => Err(format!(
                    "unexpected quantized canonical state for optimizer {other}"
                )),
            },
            OptPayload::Full(blob) => match self.name.as_str() {
                "galore" => scatter_galore(blob, world, metas),
                "qgalore" => scatter_galore(&unwrap_qgalore(blob)?, world, metas),
                "adamw" => scatter_moments(blob, world, metas, 2),
                "sgdm" => scatter_moments(blob, world, metas, 1),
                other => {
                    if world == 1 {
                        // A world of one holds the full state: frame it
                        // behind a dormant SVD-stream prefix.
                        let mut frame = dormant_svd_stream();
                        frame.extend_from_slice(blob);
                        Ok(vec![frame])
                    } else if other == "adam8bit" {
                        // Legacy (pre-v5) full blob: dequantized moments.
                        let (t, states) = parse_adam8bit(blob)?;
                        scatter_adam8bit(t, &states, world, metas, opts)
                    } else if other == "adafactor" {
                        let (t, states) = parse_adafactor(blob)?;
                        scatter_adafactor(t, &states, world, metas, opts)
                    } else {
                        Err(format!(
                            "cannot re-shard {other} optimizer state across \
                             world={world} FSDP ranks; supported: {}",
                            RESHARDABLE.join(", ")
                        ))
                    }
                }
            },
        }
    }

    /// The full-tensor blob for a single-process or DDP (replicated)
    /// import, in the importing optimizer's own state layout.
    pub fn to_full(&self, metas: &[ParamMeta], opts: ImportOpts) -> Result<Vec<u8>, String> {
        match &self.payload {
            OptPayload::Full(blob) => Ok(blob.clone()),
            OptPayload::Quantized { t, states } => match self.name.as_str() {
                // Full-tensor stored representations serialize exactly.
                "adam8bit" => write_adam8bit(*t, states),
                "adafactor" => write_adafactor(*t, states),
                other => Err(format!(
                    "unexpected quantized canonical state for optimizer {other}"
                )),
            },
            OptPayload::PerRank { frames } if frames.len() == 1 => {
                // A world-1 FSDP frame holds the full state behind its
                // SVD-stream prefix.
                if frames[0].len() < Pcg64::STATE_BYTES {
                    return Err("truncated per-rank optimizer frame".into());
                }
                Ok(frames[0][Pcg64::STATE_BYTES..].to_vec())
            }
            OptPayload::PerRank { frames } => match self.name.as_str() {
                "adam8bit" if opts.requantize => {
                    let (t, states) = merge_adam8bit_frames(frames, metas)?;
                    write_adam8bit(t, &states)
                }
                "adafactor" if opts.requantize => {
                    let (t, states) = merge_adafactor_frames(frames, metas)?;
                    write_adafactor(t, &states)
                }
                "adam8bit" | "adafactor" => Err(format!(
                    "{} optimizer state is world-locked (captured per-rank at \
                     world={}); resume with --parallel fsdp --world {} for a \
                     bitwise continuation, or pass --resume-requantize to accept \
                     an approximate gathered import",
                    self.name,
                    frames.len(),
                    frames.len(),
                )),
                _ => Err(format!(
                    "{} optimizer state is world-locked (captured per-rank at \
                     world={}); resume with --parallel fsdp --world {} or train \
                     with a re-shardable optimizer ({})",
                    self.name,
                    frames.len(),
                    frames.len(),
                    RESHARDABLE.join(", ")
                )),
            },
        }
    }
}

/// A never-drawn SVD-stream position for frames of optimizers that hold no
/// RNG (AdamW/SGDM under FSDP never compute subspaces).
fn dormant_svd_stream() -> Vec<u8> {
    let mut out = Vec::with_capacity(Pcg64::STATE_BYTES);
    Pcg64::new(0, 0x6a10).write_state(&mut out);
    out
}

/// Split an FSDP worker frame into its `[svd_rng][optimizer blob]` parts.
fn split_frame(frame: &[u8], rank: usize) -> Result<(&[u8], &[u8]), String> {
    if frame.len() < Pcg64::STATE_BYTES {
        return Err(format!("rank {rank}: truncated FSDP worker frame"));
    }
    Ok(frame.split_at(Pcg64::STATE_BYTES))
}

/// Slice one shard out of a row-major `rows`×`cols` tensor stored as a flat
/// vec. Empty inputs stay empty (lazily-unsized GaLore moments).
fn slice_vec(
    full: &[f32],
    rows: usize,
    cols: usize,
    axis: ShardAxis,
    world: usize,
    rank: usize,
) -> Vec<f32> {
    if full.is_empty() {
        return Vec::new();
    }
    match axis {
        ShardAxis::Rows => {
            let (lo, hi) = shard_bounds(rows, world, rank);
            full[lo * cols..hi * cols].to_vec()
        }
        ShardAxis::Cols => {
            let (lo, hi) = shard_bounds(cols, world, rank);
            let mut out = Vec::with_capacity(rows * (hi - lo));
            for r in 0..rows {
                out.extend_from_slice(&full[r * cols + lo..r * cols + hi]);
            }
            out
        }
    }
}

/// Concatenate per-rank shards (rank order) back into the full row-major
/// tensor. All-empty inputs gather to empty (lazily-unsized moments are
/// unsized on every rank in lockstep).
fn concat_vecs(
    parts: &[Vec<f32>],
    rows: usize,
    cols: usize,
    axis: ShardAxis,
    what: &str,
) -> Result<Vec<f32>, String> {
    let world = parts.len();
    let total: usize = parts.iter().map(|p| p.len()).sum();
    if total == 0 {
        return Ok(Vec::new());
    }
    if total != rows * cols {
        return Err(format!(
            "{what}: per-rank moments sum to {total} elements, expected {rows}x{cols}"
        ));
    }
    match axis {
        ShardAxis::Rows => {
            let mut out = Vec::with_capacity(rows * cols);
            for (rank, p) in parts.iter().enumerate() {
                let (lo, hi) = shard_bounds(rows, world, rank);
                if p.len() != (hi - lo) * cols {
                    return Err(format!(
                        "{what}: rank {rank} holds {} moment elements, expected {}",
                        p.len(),
                        (hi - lo) * cols
                    ));
                }
                out.extend_from_slice(p);
            }
            Ok(out)
        }
        ShardAxis::Cols => {
            let mut out = vec![0f32; rows * cols];
            for (rank, p) in parts.iter().enumerate() {
                let (lo, hi) = shard_bounds(cols, world, rank);
                let w = hi - lo;
                if p.len() != rows * w {
                    return Err(format!(
                        "{what}: rank {rank} holds {} moment elements, expected {}",
                        p.len(),
                        rows * w
                    ));
                }
                for r in 0..rows {
                    out[r * cols + lo..r * cols + hi]
                        .copy_from_slice(&p[r * w..(r + 1) * w]);
                }
            }
            Ok(out)
        }
    }
}

// ---------------------------------------------------------------------------
// GaLore state codec (format defined by `optim::galore::export_state`)
// ---------------------------------------------------------------------------

/// Whether a blob leads with the stored-representation format gate
/// (`optim::ser::STATE_MAGIC2`); legacy blobs lead with a small counter.
fn sniff_magic2(bytes: &[u8]) -> bool {
    crate::optim::ser::sniff_magic2(bytes)
}

enum GaloreParamState {
    Full {
        m: Vec<f32>,
        v: Vec<f32>,
    },
    LowRank {
        last_refresh: u64,
        side: u64,
        /// The projector's exact stored representation — codes + block
        /// scales for quantized kinds. Legacy (v1) blobs parse into the
        /// `F32` arm.
        p: StoredTensor,
        m: Vec<f32>,
        v: Vec<f32>,
    },
}

struct GaloreBlob {
    t: u64,
    refreshes: u64,
    rng: Vec<u8>,
    states: Vec<(usize, GaloreParamState)>,
}

fn parse_galore(bytes: &[u8]) -> Result<GaloreBlob, String> {
    let mut r = Reader::new(bytes);
    let first = r.u64()?;
    let v2 = first == STATE_MAGIC2;
    let t = if v2 { r.u64()? } else { first };
    let refreshes = r.u64()?;
    let rng = r.bytes(Pcg64::STATE_BYTES)?.to_vec();
    let n = r.u64()? as usize;
    // Every state is at least [idx][tag] = 16 bytes: reject corrupt
    // counts before allocating.
    if n > r.remaining() / 16 {
        return Err(format!("galore state count {n} exceeds blob size"));
    }
    let mut states = Vec::with_capacity(n);
    for _ in 0..n {
        let idx = r.u64()? as usize;
        let tag = r.u64()?;
        let state = if tag == 0 {
            GaloreParamState::Full {
                m: r.f32s()?,
                v: r.f32s()?,
            }
        } else {
            let last_refresh = r.u64()?;
            let side = r.u64()?;
            let p = if v2 {
                StoredTensor::decode(&mut r)?
            } else {
                // v1: dequantized f32 projector behind explicit dims —
                // one shared parser (quant) with the optimizer's own gate.
                StoredTensor::decode_legacy_f32(&mut r)?
            };
            GaloreParamState::LowRank {
                last_refresh,
                side,
                p,
                m: r.f32s()?,
                v: r.f32s()?,
            }
        };
        states.push((idx, state));
    }
    Ok(GaloreBlob {
        t,
        refreshes,
        rng,
        states,
    })
}

/// Serialize in the CURRENT (v2, stored-representation) layout — the exact
/// bytes `optim::galore::export_state` writes; a legacy blob routed
/// through parse∘write therefore migrates to v2.
fn write_galore(b: &GaloreBlob) -> Vec<u8> {
    let mut out = Vec::new();
    push_u64(&mut out, STATE_MAGIC2);
    push_u64(&mut out, b.t);
    push_u64(&mut out, b.refreshes);
    out.extend_from_slice(&b.rng);
    push_u64(&mut out, b.states.len() as u64);
    for (idx, st) in &b.states {
        push_u64(&mut out, *idx as u64);
        match st {
            GaloreParamState::Full { m, v } => {
                push_u64(&mut out, 0);
                push_f32s(&mut out, m);
                push_f32s(&mut out, v);
            }
            GaloreParamState::LowRank {
                last_refresh,
                side,
                p,
                m,
                v,
            } => {
                push_u64(&mut out, 1);
                push_u64(&mut out, *last_refresh);
                push_u64(&mut out, *side);
                p.encode(&mut out);
                push_f32s(&mut out, m);
                push_f32s(&mut out, v);
            }
        }
    }
    out
}

/// Full shape of a low-rank moment tensor: Left projectors (wide params)
/// hold r×n moments, Right projectors (tall params) hold m×r.
fn low_rank_shape(side: u64, p_cols: usize, meta: &ParamMeta) -> (usize, usize) {
    if side == 0 {
        (p_cols, meta.cols)
    } else {
        (meta.rows, p_cols)
    }
}

fn meta_for(metas: &[ParamMeta], idx: usize) -> Result<&ParamMeta, String> {
    metas
        .get(idx)
        .ok_or_else(|| format!("optimizer state names parameter {idx}, model has {}", metas.len()))
}

/// Gather per-rank GaLore worker frames into the single-process blob. The
/// leader's (rank 0's) SVD-stream position becomes the canonical RNG — the
/// same `0x6a10` stream a single-process optimizer draws its sketches
/// from, so a resumed run in ANY mode continues the identical sketch
/// sequence.
fn gather_galore(frames: &[Vec<u8>], metas: &[ParamMeta]) -> Result<Vec<u8>, String> {
    if frames.is_empty() {
        return Err("no worker frames to gather".into());
    }
    let world = frames.len();
    let mut svd_rng = Vec::new();
    let mut blobs = Vec::with_capacity(world);
    for (rank, frame) in frames.iter().enumerate() {
        let (rng, blob) = split_frame(frame, rank)?;
        if rank == 0 {
            svd_rng = rng.to_vec();
        }
        blobs.push(parse_galore(blob).map_err(|e| format!("rank {rank}: {e}"))?);
    }
    let leader = &blobs[0];
    for (rank, b) in blobs.iter().enumerate() {
        if b.t != leader.t || b.states.len() != leader.states.len() {
            return Err(format!(
                "rank {rank} optimizer state out of lockstep with rank 0 \
                 (t {} vs {}, {} vs {} states)",
                b.t,
                leader.t,
                b.states.len(),
                leader.states.len()
            ));
        }
    }
    let mut states = Vec::with_capacity(leader.states.len());
    for (si, (idx, s0)) in leader.states.iter().enumerate() {
        let meta = meta_for(metas, *idx)?;
        let axis = shard_axis(meta.rows, meta.cols);
        // Pull this state's moment shards from every rank, checking the
        // ranks agree on the state's index and kind.
        let mut ms = Vec::with_capacity(world);
        let mut vs = Vec::with_capacity(world);
        for (rank, b) in blobs.iter().enumerate() {
            let (ri, rs) = &b.states[si];
            if ri != idx {
                return Err(format!(
                    "rank {rank}: state {si} is for parameter {ri}, rank 0 has {idx}"
                ));
            }
            match (s0, rs) {
                (GaloreParamState::Full { .. }, GaloreParamState::Full { m, v }) => {
                    ms.push(m.clone());
                    vs.push(v.clone());
                }
                (
                    GaloreParamState::LowRank { .. },
                    GaloreParamState::LowRank { m, v, .. },
                ) => {
                    ms.push(m.clone());
                    vs.push(v.clone());
                }
                _ => {
                    return Err(format!(
                        "rank {rank}: parameter {idx} state kind differs from rank 0"
                    ))
                }
            }
        }
        let gathered = match s0 {
            GaloreParamState::Full { .. } => GaloreParamState::Full {
                m: concat_vecs(&ms, meta.rows, meta.cols, axis, &meta.name)?,
                v: concat_vecs(&vs, meta.rows, meta.cols, axis, &meta.name)?,
            },
            GaloreParamState::LowRank {
                last_refresh,
                side,
                p,
                ..
            } => {
                // P is replicated (it spans the un-sharded dimension), so
                // rank 0's copy IS the full projector — carried in its
                // exact stored representation.
                let (lm, ln) = low_rank_shape(*side, p.cols(), meta);
                GaloreParamState::LowRank {
                    last_refresh: *last_refresh,
                    side: *side,
                    p: p.clone(),
                    m: concat_vecs(&ms, lm, ln, axis, &meta.name)?,
                    v: concat_vecs(&vs, lm, ln, axis, &meta.name)?,
                }
            }
        };
        states.push((*idx, gathered));
    }
    Ok(write_galore(&GaloreBlob {
        t: leader.t,
        refreshes: leader.refreshes,
        rng: svd_rng,
        states,
    }))
}

/// Re-slice a single-process GaLore blob into per-rank FSDP worker frames.
/// Every rank's frame carries the canonical RNG position; only the leader
/// ever draws from it, continuing the exact stream the source run (single,
/// DDP, or FSDP at any world) would have used.
fn scatter_galore(
    blob: &[u8],
    world: usize,
    metas: &[ParamMeta],
) -> Result<Vec<Vec<u8>>, String> {
    let b = parse_galore(blob)?;
    let mut frames = Vec::with_capacity(world);
    for rank in 0..world {
        let mut states = Vec::with_capacity(b.states.len());
        for (idx, st) in &b.states {
            let meta = meta_for(metas, *idx)?;
            let axis = shard_axis(meta.rows, meta.cols);
            let sliced = match st {
                GaloreParamState::Full { m, v } => {
                    for (name, mom) in [("m", m), ("v", v)] {
                        if !mom.is_empty() && mom.len() != meta.rows * meta.cols {
                            return Err(format!(
                                "{}: canonical {name} moment has {} elements, expected {}x{}",
                                meta.name,
                                mom.len(),
                                meta.rows,
                                meta.cols
                            ));
                        }
                    }
                    GaloreParamState::Full {
                        m: slice_vec(m, meta.rows, meta.cols, axis, world, rank),
                        v: slice_vec(v, meta.rows, meta.cols, axis, world, rank),
                    }
                }
                GaloreParamState::LowRank {
                    last_refresh,
                    side,
                    p,
                    m,
                    v,
                } => {
                    let (lm, ln) = low_rank_shape(*side, p.cols(), meta);
                    for (name, mom) in [("m", m), ("v", v)] {
                        if !mom.is_empty() && mom.len() != lm * ln {
                            return Err(format!(
                                "{}: canonical low-rank {name} moment has {} elements, \
                                 expected {lm}x{ln}",
                                meta.name,
                                mom.len()
                            ));
                        }
                    }
                    GaloreParamState::LowRank {
                        last_refresh: *last_refresh,
                        side: *side,
                        p: p.clone(),
                        m: slice_vec(m, lm, ln, axis, world, rank),
                        v: slice_vec(v, lm, ln, axis, world, rank),
                    }
                }
            };
            states.push((*idx, sliced));
        }
        let mut frame = b.rng.clone();
        frame.extend_from_slice(&write_galore(&GaloreBlob {
            t: b.t,
            refreshes: b.refreshes,
            rng: b.rng.clone(),
            states,
        }));
        frames.push(frame);
    }
    Ok(frames)
}

// ---------------------------------------------------------------------------
// Q-GaLore framing (format defined by `optim::qgalore::export_state`)
// ---------------------------------------------------------------------------

/// Wrap a GaLore blob in Q-GaLore's framing with an empty lazy-gate: under
/// FSDP the gate is inert (the coordinator owns refreshes), so gathered
/// state carries no gate history — a single/DDP resume re-seeds the gate
/// from its first post-resume refresh probe.
fn wrap_qgalore(inner: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::new();
    push_u64(&mut out, inner.len() as u64);
    out.extend_from_slice(&inner);
    push_u64(&mut out, 0); // refreshes skipped by the gate
    push_u64(&mut out, 0); // refreshes taken
    push_u64(&mut out, 0); // no per-parameter probe directions
    out
}

/// Extract the inner GaLore blob from Q-GaLore framing (the gate state is
/// dropped: it is inert under FSDP).
fn unwrap_qgalore(blob: &[u8]) -> Result<Vec<u8>, String> {
    let mut r = Reader::new(blob);
    let len = r.u64()? as usize;
    Ok(r.bytes(len)?.to_vec())
}

// ---------------------------------------------------------------------------
// Plain moment-map codec (AdamW: 2 moment tensors; SGDM: 1) — format
// defined by `optim::adamw::export_state` / `optim::sgdm::export_state`:
// `[t u64][n u64]` then per state `[idx u64]` + nmoments length-framed f32
// vectors.
// ---------------------------------------------------------------------------

type MomentStates = Vec<(usize, Vec<Vec<f32>>)>;

fn parse_moments(bytes: &[u8], nmoments: usize) -> Result<(u64, MomentStates), String> {
    let mut r = Reader::new(bytes);
    let t = r.u64()?;
    let n = r.u64()? as usize;
    // Every state is at least [idx] + nmoments length headers: reject
    // corrupt counts before allocating.
    if n > r.remaining() / (8 * (1 + nmoments)) {
        return Err(format!("optimizer state count {n} exceeds blob size"));
    }
    let mut states = Vec::with_capacity(n);
    for _ in 0..n {
        let idx = r.u64()? as usize;
        let mut moments = Vec::with_capacity(nmoments);
        for _ in 0..nmoments {
            moments.push(r.f32s()?);
        }
        states.push((idx, moments));
    }
    Ok((t, states))
}

fn write_moments(t: u64, states: &MomentStates) -> Vec<u8> {
    let mut out = Vec::new();
    push_u64(&mut out, t);
    push_u64(&mut out, states.len() as u64);
    for (idx, moments) in states {
        push_u64(&mut out, *idx as u64);
        for m in moments {
            push_f32s(&mut out, m);
        }
    }
    out
}

fn gather_moments(
    frames: &[Vec<u8>],
    metas: &[ParamMeta],
    nmoments: usize,
) -> Result<Vec<u8>, String> {
    if frames.is_empty() {
        return Err("no worker frames to gather".into());
    }
    let world = frames.len();
    let mut per_rank = Vec::with_capacity(world);
    for (rank, frame) in frames.iter().enumerate() {
        let (_rng, blob) = split_frame(frame, rank)?;
        per_rank.push(parse_moments(blob, nmoments).map_err(|e| format!("rank {rank}: {e}"))?);
    }
    let (t, leader) = &per_rank[0];
    for (rank, (rt, rs)) in per_rank.iter().enumerate() {
        if rt != t || rs.len() != leader.len() {
            return Err(format!(
                "rank {rank} optimizer state out of lockstep with rank 0"
            ));
        }
    }
    let mut states = Vec::with_capacity(leader.len());
    for (si, (idx, _)) in leader.iter().enumerate() {
        let meta = meta_for(metas, *idx)?;
        let axis = shard_axis(meta.rows, meta.cols);
        let mut moments = Vec::with_capacity(nmoments);
        for k in 0..nmoments {
            let mut parts = Vec::with_capacity(world);
            for (rank, (_, rs)) in per_rank.iter().enumerate() {
                let (ri, rm) = &rs[si];
                if ri != idx {
                    return Err(format!(
                        "rank {rank}: state {si} is for parameter {ri}, rank 0 has {idx}"
                    ));
                }
                parts.push(rm[k].clone());
            }
            moments.push(concat_vecs(&parts, meta.rows, meta.cols, axis, &meta.name)?);
        }
        states.push((*idx, moments));
    }
    Ok(write_moments(*t, &states))
}

fn scatter_moments(
    blob: &[u8],
    world: usize,
    metas: &[ParamMeta],
    nmoments: usize,
) -> Result<Vec<Vec<u8>>, String> {
    let (t, states) = parse_moments(blob, nmoments)?;
    let mut frames = Vec::with_capacity(world);
    for rank in 0..world {
        let mut sliced = Vec::with_capacity(states.len());
        for (idx, moments) in &states {
            let meta = meta_for(metas, *idx)?;
            let axis = shard_axis(meta.rows, meta.cols);
            let mut shards = Vec::with_capacity(nmoments);
            for m in moments {
                if m.len() != meta.rows * meta.cols {
                    return Err(format!(
                        "{}: canonical moment has {} elements, expected {}x{}",
                        meta.name,
                        m.len(),
                        meta.rows,
                        meta.cols
                    ));
                }
                shards.push(slice_vec(m, meta.rows, meta.cols, axis, world, rank));
            }
            sliced.push((*idx, shards));
        }
        let mut frame = dormant_svd_stream();
        frame.extend_from_slice(&write_moments(t, &sliced));
        frames.push(frame);
    }
    Ok(frames)
}

// ---------------------------------------------------------------------------
// Adam8bit codec (format defined by `optim::adam8bit::export_state`):
// `[STATE_MAGIC2][t][n]` then per state `[idx][q8 m][q8 v]` in the shared
// quant block codec. Legacy (pre-v5) blobs are `[t][n]` + dequantized f32
// moment vectors; they parse into `CanonicalTensor::F32` arms.
// ---------------------------------------------------------------------------

fn parse_adam8bit(bytes: &[u8]) -> Result<(u64, QuantStates), String> {
    let mut r = Reader::new(bytes);
    let first = r.u64()?;
    let v2 = first == STATE_MAGIC2;
    let t = if v2 { r.u64()? } else { first };
    let n = r.u64()? as usize;
    // Every state is at least [idx] + two tensor headers.
    if n > r.remaining() / 24 {
        return Err(format!("adam8bit state count {n} exceeds blob size"));
    }
    let mut states = Vec::with_capacity(n);
    for _ in 0..n {
        let idx = r.u64()? as usize;
        let (m, v) = if v2 {
            (
                CanonicalTensor::Q8(Quantized8::decode(&mut r)?),
                CanonicalTensor::Q8(Quantized8::decode(&mut r)?),
            )
        } else {
            (
                CanonicalTensor::F32(r.f32s()?),
                CanonicalTensor::F32(r.f32s()?),
            )
        };
        states.push((idx, vec![m, v]));
    }
    Ok((t, states))
}

/// Serialize in the CURRENT (stored-representation) adam8bit layout.
/// Requires quantized tensors — f32 moments must be quantized first (the
/// scatter/merge paths do this under the `requantize` opt-in).
fn write_adam8bit(t: u64, states: &QuantStates) -> Result<Vec<u8>, String> {
    let mut out = Vec::new();
    push_u64(&mut out, STATE_MAGIC2);
    push_u64(&mut out, t);
    push_u64(&mut out, states.len() as u64);
    for (idx, tensors) in states {
        push_u64(&mut out, *idx as u64);
        if tensors.len() != 2 {
            return Err(format!(
                "adam8bit canonical state holds {} tensors for parameter {idx}, expected 2",
                tensors.len()
            ));
        }
        for tensor in tensors {
            match tensor {
                CanonicalTensor::Q8(q) => q.encode(&mut out),
                CanonicalTensor::F32(_) => {
                    return Err(
                        "adam8bit canonical state holds non-quantized tensors".into()
                    )
                }
            }
        }
    }
    Ok(out)
}

/// Flat element ranges (row-major order of the FULL tensor) that `rank`'s
/// shard covers, in shard-local order: one contiguous run for row-sharded
/// (tall) parameters, one run per row for column-sharded (wide) ones.
fn shard_flat_ranges(meta: &ParamMeta, world: usize, rank: usize) -> Vec<(usize, usize)> {
    match shard_axis(meta.rows, meta.cols) {
        ShardAxis::Rows => {
            let (lo, hi) = shard_bounds(meta.rows, world, rank);
            vec![(lo * meta.cols, hi * meta.cols)]
        }
        ShardAxis::Cols => {
            let (lo, hi) = shard_bounds(meta.cols, world, rank);
            (0..meta.rows)
                .map(|r| (r * meta.cols + lo, r * meta.cols + hi))
                .collect()
        }
    }
}

/// Whether every rank's shard of this parameter decomposes into whole
/// [`BLOCK`]-element quantization blocks of the full flattened tensor
/// (the tensor's final partial block excepted). Exactly then do the
/// per-rank block quantizations coincide with the full-tensor one, and
/// block-quantized state re-slices across worlds bit-for-bit.
fn shards_block_aligned(meta: &ParamMeta, world: usize) -> bool {
    let total = meta.rows * meta.cols;
    (0..world).all(|rank| {
        shard_flat_ranges(meta, world, rank)
            .iter()
            .all(|&(s, e)| s == e || (s % BLOCK == 0 && (e % BLOCK == 0 || e == total)))
    })
}

/// Slice a full-tensor block-quantized moment for one rank, EXACTLY —
/// callers must have established block alignment via
/// [`shards_block_aligned`].
fn slice_q8(
    q: &Quantized8,
    meta: &ParamMeta,
    world: usize,
    rank: usize,
) -> Result<Quantized8, String> {
    if q.len != meta.rows * meta.cols {
        return Err(format!(
            "{}: canonical quantized moment has {} elements, expected {}x{}",
            meta.name, q.len, meta.rows, meta.cols
        ));
    }
    let mut codes = Vec::new();
    let mut scales = Vec::new();
    let mut len = 0usize;
    for (s, e) in shard_flat_ranges(meta, world, rank) {
        if s == e {
            continue;
        }
        codes.extend_from_slice(&q.codes[s..e]);
        scales.extend_from_slice(&q.scales[s / BLOCK..e.div_ceil(BLOCK)]);
        len += e - s;
    }
    Ok(Quantized8 { codes, scales, len })
}

/// Reassemble the full-tensor block-quantized moment from per-rank
/// shards — the exact inverse of [`slice_q8`] under block alignment.
fn concat_q8(parts: &[&Quantized8], meta: &ParamMeta) -> Result<Quantized8, String> {
    let total = meta.rows * meta.cols;
    let world = parts.len();
    let mut codes = vec![0u8; total];
    let mut scales = vec![0f32; total.div_ceil(BLOCK)];
    for (rank, q) in parts.iter().enumerate() {
        let mut cpos = 0usize; // cursor into the rank's local codes
        let mut spos = 0usize; // cursor into the rank's local scales
        for (s, e) in shard_flat_ranges(meta, world, rank) {
            if s == e {
                continue;
            }
            let n = e - s;
            let nb = e.div_ceil(BLOCK) - s / BLOCK;
            if cpos + n > q.codes.len() || spos + nb > q.scales.len() {
                return Err(format!(
                    "{}: rank {rank} quantized moment is shorter than its shard",
                    meta.name
                ));
            }
            codes[s..e].copy_from_slice(&q.codes[cpos..cpos + n]);
            scales[s / BLOCK..e.div_ceil(BLOCK)].copy_from_slice(&q.scales[spos..spos + nb]);
            cpos += n;
            spos += nb;
        }
        if q.len != cpos || cpos != q.codes.len() || spos != q.scales.len() {
            return Err(format!(
                "{}: rank {rank} quantized moment does not tile the canonical blocks",
                meta.name
            ));
        }
    }
    Ok(Quantized8 {
        codes,
        scales,
        len: total,
    })
}

/// Parse every rank's `[svd_rng][blob]` frame with `parse` and enforce the
/// cross-rank lockstep invariants — same step counter, same state count,
/// same parameter order — shared by every per-rank gather/merge below.
fn parse_rank_states(
    frames: &[Vec<u8>],
    parse: fn(&[u8]) -> Result<(u64, QuantStates), String>,
) -> Result<(u64, Vec<QuantStates>), String> {
    if frames.is_empty() {
        return Err("no worker frames to gather".into());
    }
    let mut per_rank = Vec::with_capacity(frames.len());
    for (rank, frame) in frames.iter().enumerate() {
        let (_rng, blob) = split_frame(frame, rank)?;
        per_rank.push(parse(blob).map_err(|e| format!("rank {rank}: {e}"))?);
    }
    let t = per_rank[0].0;
    let n = per_rank[0].1.len();
    for (rank, (rt, rs)) in per_rank.iter().enumerate() {
        if *rt != t || rs.len() != n {
            return Err(format!(
                "rank {rank} optimizer state out of lockstep with rank 0"
            ));
        }
    }
    for si in 0..n {
        let idx = per_rank[0].1[si].0;
        for (rank, (_, rs)) in per_rank.iter().enumerate() {
            if rs[si].0 != idx {
                return Err(format!(
                    "rank {rank}: state {si} is for parameter {}, rank 0 has {idx}",
                    rs[si].0
                ));
            }
        }
    }
    Ok((t, per_rank.into_iter().map(|(_, rs)| rs).collect()))
}

/// Gather per-rank Adam8bit frames. Exact — producing the typed
/// [`OptPayload::Quantized`] flavor, byte-identical to a single-process
/// export — when every sharded parameter is block-aligned and every rank
/// exported the stored (v2) representation; otherwise the lossless
/// world-locked [`OptPayload::PerRank`] fallback.
fn gather_adam8bit(frames: Vec<Vec<u8>>, metas: &[ParamMeta]) -> Result<OptPayload, String> {
    let (t, per_rank) = parse_rank_states(&frames, parse_adam8bit)?;
    let world = frames.len();
    let aligned = per_rank[0].iter().all(|(idx, _)| {
        metas
            .get(*idx)
            .map_or(false, |m| shards_block_aligned(m, world))
    }) && per_rank.iter().all(|rs| {
        rs.iter()
            .all(|(_, ts)| ts.iter().all(|ct| matches!(ct, CanonicalTensor::Q8(_))))
    });
    if !aligned {
        return Ok(OptPayload::PerRank { frames });
    }
    let mut states = Vec::with_capacity(per_rank[0].len());
    for si in 0..per_rank[0].len() {
        let idx = per_rank[0][si].0;
        let meta = meta_for(metas, idx)?;
        let mut tensors = Vec::with_capacity(2);
        for k in 0..2 {
            let mut parts = Vec::with_capacity(world);
            for rs in &per_rank {
                match &rs[si].1[k] {
                    CanonicalTensor::Q8(q) => parts.push(q),
                    CanonicalTensor::F32(_) => unreachable!("alignment check ensured Q8"),
                }
            }
            tensors.push(CanonicalTensor::Q8(concat_q8(&parts, meta)?));
        }
        states.push((idx, tensors));
    }
    Ok(OptPayload::Quantized { t, states })
}

/// Re-slice full-tensor Adam8bit state into per-rank frames: EXACT (codes
/// + scales sliced along quant-block boundaries) when the geometry is
/// block-aligned and the state is quantized; otherwise a LOSSY
/// dequantize→slice→requantize, gated on [`ImportOpts::requantize`] and
/// announced on stderr.
fn scatter_adam8bit(
    t: u64,
    states: &QuantStates,
    world: usize,
    metas: &[ParamMeta],
    opts: ImportOpts,
) -> Result<Vec<Vec<u8>>, String> {
    let exact = states.iter().all(|(idx, tensors)| {
        metas
            .get(*idx)
            .map_or(false, |m| shards_block_aligned(m, world))
            && tensors
                .iter()
                .all(|ct| matches!(ct, CanonicalTensor::Q8(_)))
    });
    if !exact && !opts.requantize {
        return Err(format!(
            "adam8bit optimizer state cannot be re-sliced exactly for world={world}: \
             shard boundaries do not align with the {BLOCK}-element quantization \
             blocks (or the checkpoint predates stored-representation state); pass \
             --resume-requantize to accept a lossy re-quantized import"
        ));
    }
    if !exact {
        eprintln!(
            "[resume] re-quantizing adam8bit moments for world={world} \
             (lossy; opted in via --resume-requantize)"
        );
    }
    let mut frames = Vec::with_capacity(world);
    for rank in 0..world {
        let mut sliced: QuantStates = Vec::with_capacity(states.len());
        for (idx, tensors) in states {
            let meta = meta_for(metas, *idx)?;
            let axis = shard_axis(meta.rows, meta.cols);
            let mut out_tensors = Vec::with_capacity(tensors.len());
            for tensor in tensors {
                let q = if exact {
                    match tensor {
                        CanonicalTensor::Q8(q) => slice_q8(q, meta, world, rank)?,
                        CanonicalTensor::F32(_) => unreachable!("exact implies Q8"),
                    }
                } else {
                    let full = tensor.values();
                    if full.len() != meta.rows * meta.cols {
                        return Err(format!(
                            "{}: canonical moment has {} elements, expected {}x{}",
                            meta.name,
                            full.len(),
                            meta.rows,
                            meta.cols
                        ));
                    }
                    Quantized8::quantize(&slice_vec(
                        &full, meta.rows, meta.cols, axis, world, rank,
                    ))
                };
                out_tensors.push(CanonicalTensor::Q8(q));
            }
            sliced.push((*idx, out_tensors));
        }
        let mut frame = dormant_svd_stream();
        frame.extend_from_slice(&write_adam8bit(t, &sliced)?);
        frames.push(frame);
    }
    Ok(frames)
}

/// Merge world-locked per-rank Adam8bit frames into full-tensor state
/// (requantize opt-in): shards are dequantized, reassembled, and the full
/// tensor re-quantized with full-tensor blocks.
fn merge_adam8bit_frames(
    frames: &[Vec<u8>],
    metas: &[ParamMeta],
) -> Result<(u64, QuantStates), String> {
    let (t, per_rank) = parse_rank_states(frames, parse_adam8bit)?;
    let world = frames.len();
    eprintln!(
        "[resume] merging adam8bit moments captured per-rank at world={world} \
         (re-quantized with full-tensor blocks; opted in via --resume-requantize)"
    );
    let mut states: QuantStates = Vec::with_capacity(per_rank[0].len());
    for si in 0..per_rank[0].len() {
        let idx = per_rank[0][si].0;
        let meta = meta_for(metas, idx)?;
        let axis = shard_axis(meta.rows, meta.cols);
        let mut tensors = Vec::with_capacity(2);
        for k in 0..2 {
            let parts: Vec<Vec<f32>> =
                per_rank.iter().map(|rs| rs[si].1[k].values()).collect();
            let full = concat_vecs(&parts, meta.rows, meta.cols, axis, &meta.name)?;
            tensors.push(CanonicalTensor::Q8(Quantized8::quantize(&full)));
        }
        states.push((idx, tensors));
    }
    Ok((t, states))
}

// ---------------------------------------------------------------------------
// Adafactor codec (format defined by `optim::adafactor::export_state`):
// `[t][n]` then per state `[idx][f32s row][f32s col]`. The full-tensor
// canonical form carries both factored accumulators as f32 tensors; only
// the factor along the shard axis re-slices exactly — the cross factor is
// a rank-local statistic, so cross-world conversions are approximate and
// sit behind the `requantize` opt-in.
// ---------------------------------------------------------------------------

fn parse_adafactor(bytes: &[u8]) -> Result<(u64, QuantStates), String> {
    let mut r = Reader::new(bytes);
    let t = r.u64()?;
    let n = r.u64()? as usize;
    if n > r.remaining() / 24 {
        return Err(format!("adafactor state count {n} exceeds blob size"));
    }
    let mut states = Vec::with_capacity(n);
    for _ in 0..n {
        let idx = r.u64()? as usize;
        let row = CanonicalTensor::F32(r.f32s()?);
        let col = CanonicalTensor::F32(r.f32s()?);
        states.push((idx, vec![row, col]));
    }
    Ok((t, states))
}

fn write_adafactor(t: u64, states: &QuantStates) -> Result<Vec<u8>, String> {
    let mut out = Vec::new();
    push_u64(&mut out, t);
    push_u64(&mut out, states.len() as u64);
    for (idx, tensors) in states {
        push_u64(&mut out, *idx as u64);
        if tensors.len() != 2 {
            return Err(format!(
                "adafactor canonical state holds {} tensors for parameter {idx}, expected 2",
                tensors.len()
            ));
        }
        for tensor in tensors {
            match tensor {
                CanonicalTensor::F32(xs) => push_f32s(&mut out, xs),
                CanonicalTensor::Q8(_) => {
                    return Err("adafactor canonical state holds quantized tensors".into())
                }
            }
        }
    }
    Ok(out)
}

/// Expect an adafactor state's `[row, col]` f32 pair with full-tensor
/// lengths.
fn adafactor_row_col<'a>(
    tensors: &'a [CanonicalTensor],
    meta: &ParamMeta,
) -> Result<(&'a [f32], &'a [f32]), String> {
    match tensors {
        [CanonicalTensor::F32(row), CanonicalTensor::F32(col)]
            if row.len() == meta.rows && col.len() == meta.cols =>
        {
            Ok((row, col))
        }
        _ => Err(format!(
            "{}: adafactor canonical state does not hold full {}-row/{}-col \
             f32 accumulators",
            meta.name, meta.rows, meta.cols
        )),
    }
}

/// Re-slice full-tensor Adafactor state into per-rank frames. World 1 is
/// exact; wider worlds slice the shard-axis factor exactly but must
/// REPLICATE the cross factor (a statistic each rank would otherwise
/// accumulate over its own shard) — approximate, gated on
/// [`ImportOpts::requantize`].
fn scatter_adafactor(
    t: u64,
    states: &QuantStates,
    world: usize,
    metas: &[ParamMeta],
    opts: ImportOpts,
) -> Result<Vec<Vec<u8>>, String> {
    if world > 1 {
        if !opts.requantize {
            return Err(format!(
                "adafactor optimizer state cannot be re-sliced exactly for \
                 world={world}: the factored cross-statistic is rank-local; pass \
                 --resume-requantize to accept an approximate re-slice (shard-axis \
                 factor sliced exactly, cross factor replicated)"
            ));
        }
        eprintln!(
            "[resume] re-slicing adafactor factored state for world={world} \
             (cross factor replicated; opted in via --resume-requantize)"
        );
    }
    let mut frames = Vec::with_capacity(world);
    for rank in 0..world {
        let mut sliced: QuantStates = Vec::with_capacity(states.len());
        for (idx, tensors) in states {
            let meta = meta_for(metas, *idx)?;
            let (row, col) = adafactor_row_col(tensors, meta)?;
            let (row_s, col_s) = match shard_axis(meta.rows, meta.cols) {
                ShardAxis::Rows => {
                    let (lo, hi) = shard_bounds(meta.rows, world, rank);
                    (row[lo..hi].to_vec(), col.to_vec())
                }
                ShardAxis::Cols => {
                    let (lo, hi) = shard_bounds(meta.cols, world, rank);
                    (row.to_vec(), col[lo..hi].to_vec())
                }
            };
            sliced.push((
                *idx,
                vec![CanonicalTensor::F32(row_s), CanonicalTensor::F32(col_s)],
            ));
        }
        let mut frame = dormant_svd_stream();
        frame.extend_from_slice(&write_adafactor(t, &sliced)?);
        frames.push(frame);
    }
    Ok(frames)
}

/// Merge world-locked per-rank Adafactor frames into full-tensor form
/// (requantize opt-in): the shard-axis factor concatenates exactly; the
/// cross factor is the shard-size-weighted mean of the rank-local
/// statistics — the value a full-tensor accumulation would have produced
/// had every rank seen the same per-element squared gradients.
fn merge_adafactor_frames(
    frames: &[Vec<u8>],
    metas: &[ParamMeta],
) -> Result<(u64, QuantStates), String> {
    let (t, per_rank) = parse_rank_states(frames, parse_adafactor)?;
    let world = frames.len();
    eprintln!(
        "[resume] merging adafactor factored state captured per-rank at \
         world={world} (cross factor shard-weighted; opted in via --resume-requantize)"
    );
    let mut states: QuantStates = Vec::with_capacity(per_rank[0].len());
    for si in 0..per_rank[0].len() {
        let idx = per_rank[0][si].0;
        let meta = meta_for(metas, idx)?;
        let axis = shard_axis(meta.rows, meta.cols);
        // (sliceable length per rank, cross length) per the shard axis.
        let (slice_len, cross_len) = match axis {
            ShardAxis::Rows => (meta.rows, meta.cols),
            ShardAxis::Cols => (meta.cols, meta.rows),
        };
        let mut sliceable = Vec::with_capacity(slice_len);
        let mut cross = vec![0f32; cross_len];
        for (rank, rs) in per_rank.iter().enumerate() {
            let ts = &rs[si].1;
            let (lo, hi) = shard_bounds(slice_len, world, rank);
            let (rank_slice, rank_cross) = match (axis, ts.as_slice()) {
                (ShardAxis::Rows, [CanonicalTensor::F32(row), CanonicalTensor::F32(col)]) => {
                    (row, col)
                }
                (ShardAxis::Cols, [CanonicalTensor::F32(row), CanonicalTensor::F32(col)]) => {
                    (col, row)
                }
                _ => {
                    return Err(format!(
                        "{}: rank {rank} adafactor state is not an f32 [row, col] pair",
                        meta.name
                    ))
                }
            };
            if rank_slice.len() != hi - lo || rank_cross.len() != cross_len {
                return Err(format!(
                    "{}: rank {rank} adafactor factors have lengths {}/{}, \
                     expected {}/{cross_len}",
                    meta.name,
                    rank_slice.len(),
                    rank_cross.len(),
                    hi - lo
                ));
            }
            sliceable.extend_from_slice(rank_slice);
            let weight = (hi - lo) as f32 / slice_len as f32;
            for (acc, &x) in cross.iter_mut().zip(rank_cross.iter()) {
                *acc += weight * x;
            }
        }
        if sliceable.len() != slice_len {
            return Err(format!(
                "{}: per-rank adafactor factors do not tile the {slice_len} \
                 shard-axis entries",
                meta.name
            ));
        }
        let (row, col) = match axis {
            ShardAxis::Rows => (sliceable, cross),
            ShardAxis::Cols => (cross, sliceable),
        };
        states.push((
            idx,
            vec![CanonicalTensor::F32(row), CanonicalTensor::F32(col)],
        ));
    }
    Ok((t, states))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metas(shapes: &[(usize, usize)]) -> Vec<ParamMeta> {
        shapes
            .iter()
            .enumerate()
            .map(|(i, &(r, c))| ParamMeta {
                name: format!("p{i}"),
                rows: r,
                cols: c,
            })
            .collect()
    }

    #[test]
    fn encode_decode_roundtrip_all_flavors() {
        let full = CanonicalOptState::full("galore", vec![1, 2, 3]);
        assert_eq!(CanonicalOptState::decode(&full.encode()).unwrap(), full);
        let per_rank = CanonicalOptState {
            name: "adam8bit".into(),
            payload: OptPayload::PerRank {
                frames: vec![vec![9; 40], vec![8; 33]],
            },
        };
        assert_eq!(
            CanonicalOptState::decode(&per_rank.encode()).unwrap(),
            per_rank
        );
        let quantized = CanonicalOptState {
            name: "adam8bit".into(),
            payload: OptPayload::Quantized {
                t: 11,
                states: vec![
                    (
                        0,
                        vec![
                            CanonicalTensor::Q8(Quantized8::quantize(&[0.5; 300])),
                            CanonicalTensor::Q8(Quantized8::quantize(&[-0.25; 300])),
                        ],
                    ),
                    (
                        2,
                        vec![
                            CanonicalTensor::F32(vec![1.0, 2.0]),
                            CanonicalTensor::F32(vec![3.0]),
                        ],
                    ),
                ],
            },
        };
        assert_eq!(
            CanonicalOptState::decode(&quantized.encode()).unwrap(),
            quantized
        );
    }

    #[test]
    fn quantized_flavor_rejects_corrupt_counts_and_tags() {
        // Bit-flipped state/tensor counts and unknown storage tags must
        // error, never abort or misparse.
        let mut blob = Vec::new();
        blob.extend_from_slice(MAGIC);
        push_u64(&mut blob, 8);
        blob.extend_from_slice(b"adam8bit");
        push_u64(&mut blob, FLAVOR_QUANTIZED);
        push_u64(&mut blob, 0); // t
        push_u64(&mut blob, u64::MAX); // insane state count
        assert!(CanonicalOptState::decode(&blob).is_err());

        let mut blob = Vec::new();
        blob.extend_from_slice(MAGIC);
        push_u64(&mut blob, 8);
        blob.extend_from_slice(b"adam8bit");
        push_u64(&mut blob, FLAVOR_QUANTIZED);
        push_u64(&mut blob, 0); // t
        push_u64(&mut blob, 1); // one state
        push_u64(&mut blob, 0); // idx
        push_u64(&mut blob, u64::MAX); // insane tensor count
        assert!(CanonicalOptState::decode(&blob).is_err());

        let mut blob = Vec::new();
        blob.extend_from_slice(MAGIC);
        push_u64(&mut blob, 8);
        blob.extend_from_slice(b"adam8bit");
        push_u64(&mut blob, FLAVOR_QUANTIZED);
        push_u64(&mut blob, 0); // t
        push_u64(&mut blob, 1); // one state
        push_u64(&mut blob, 0); // idx
        push_u64(&mut blob, 1); // one tensor
        blob.push(99); // unknown storage tag
        push_u64(&mut blob, 0); // padding so the size guard passes
        let err = CanonicalOptState::decode(&blob).unwrap_err();
        assert!(err.contains("tag"), "unhelpful error: {err}");
    }

    #[test]
    fn sniff_distinguishes_legacy_blobs() {
        assert!(CanonicalOptState::sniff(
            &CanonicalOptState::full("adamw", vec![]).encode()
        ));
        // Legacy blobs start with a small little-endian counter (a step or
        // a world size), never the magic.
        let mut legacy = Vec::new();
        push_u64(&mut legacy, 7);
        assert!(!CanonicalOptState::sniff(&legacy));
        assert!(!CanonicalOptState::sniff(b"GAL"));
        assert!(!CanonicalOptState::sniff(&[]));
    }

    #[test]
    fn decode_rejects_truncation_and_garbage() {
        let blob = CanonicalOptState::full("galore", vec![0; 64]).encode();
        assert!(CanonicalOptState::decode(&blob[..blob.len() - 9]).is_err());
        let err = CanonicalOptState::decode(b"not a canonical blob....").unwrap_err();
        assert!(err.contains("GAL2OPT"), "unhelpful error: {err}");
    }

    #[test]
    fn name_mismatch_is_loud() {
        let c = CanonicalOptState::full("galore", vec![]);
        let err = c.expect_name("adamw").unwrap_err();
        assert!(err.contains("galore") && err.contains("adamw"));
    }

    #[test]
    fn slice_concat_roundtrip_all_axes_and_worlds() {
        for (rows, cols) in [(3usize, 8usize), (8, 3), (1, 5), (4, 4)] {
            let full: Vec<f32> = (0..rows * cols).map(|i| i as f32).collect();
            let axis = shard_axis(rows, cols);
            for world in [1usize, 2, 3, 4, 5, 7] {
                let parts: Vec<Vec<f32>> = (0..world)
                    .map(|r| slice_vec(&full, rows, cols, axis, world, r))
                    .collect();
                let back = concat_vecs(&parts, rows, cols, axis, "t").unwrap();
                assert_eq!(back, full, "{rows}x{cols} world {world}");
            }
        }
    }

    #[test]
    fn empty_moments_stay_empty_through_gather_and_scatter() {
        // Lazily-unsized GaLore moments are empty on every rank in
        // lockstep; the canonical form keeps them unsized.
        let parts = vec![Vec::new(), Vec::new(), Vec::new()];
        assert_eq!(
            concat_vecs(&parts, 4, 6, ShardAxis::Cols, "t").unwrap(),
            Vec::<f32>::new()
        );
        assert_eq!(
            slice_vec(&[], 4, 6, ShardAxis::Cols, 3, 1),
            Vec::<f32>::new()
        );
    }

    #[test]
    fn concat_rejects_inconsistent_shards() {
        let parts = vec![vec![0.0; 5], vec![0.0; 5]];
        let err = concat_vecs(&parts, 2, 4, ShardAxis::Cols, "p0").unwrap_err();
        assert!(err.contains("expected"), "unhelpful error: {err}");
    }

    #[test]
    fn moment_blob_scatter_gather_is_identity() {
        // gather(scatter(blob)) == blob for the AdamW codec at several
        // worlds, including worlds larger than the narrow (1, 3) bias —
        // which leaves some ranks with empty shards.
        let shapes = [(4usize, 6usize), (6, 4), (1, 3)];
        let ms = metas(&shapes);
        let states: MomentStates = shapes
            .iter()
            .enumerate()
            .map(|(i, &(r, c))| {
                let m: Vec<f32> = (0..r * c).map(|k| (i * 100 + k) as f32).collect();
                let v: Vec<f32> = (0..r * c).map(|k| (i * 100 + k) as f32 * 0.5).collect();
                (i, vec![m, v])
            })
            .collect();
        let blob = write_moments(7, &states);
        for world in [1usize, 2, 3, 4, 5] {
            let frames = scatter_moments(&blob, world, &ms, 2).unwrap();
            assert_eq!(frames.len(), world);
            let back = gather_moments(&frames, &ms, 2).unwrap();
            assert_eq!(back, blob, "world {world}: scatter/gather not identity");
        }
    }

    #[test]
    fn galore_blob_scatter_gather_is_identity() {
        // Same identity for the GaLore codec: a wide low-rank state (Left
        // projector, r×n moments), a tall one (Right, m×r), a full-rank
        // fallback, and a lazily-unsized low-rank state.
        let shapes = [(4usize, 10usize), (10, 4), (1, 6), (5, 5)];
        let ms = metas(&shapes);
        let r = 2usize;
        let f32_p = |rows: usize, cols: usize| StoredTensor::F32 {
            rows,
            cols,
            data: (0..rows * cols).map(|k| k as f32).collect(),
        };
        let states = vec![
            (
                0,
                GaloreParamState::LowRank {
                    last_refresh: 3,
                    side: 0,
                    p: f32_p(4, r),
                    m: (0..r * 10).map(|k| k as f32 + 0.25).collect(),
                    v: (0..r * 10).map(|k| k as f32 + 0.5).collect(),
                },
            ),
            (
                1,
                GaloreParamState::LowRank {
                    last_refresh: 3,
                    side: 1,
                    p: f32_p(4, r),
                    m: (0..10 * r).map(|k| k as f32 - 0.25).collect(),
                    v: (0..10 * r).map(|k| k as f32 - 0.5).collect(),
                },
            ),
            (
                2,
                GaloreParamState::Full {
                    m: (0..6).map(|k| k as f32).collect(),
                    v: (0..6).map(|k| k as f32 * 2.0).collect(),
                },
            ),
            (
                3,
                GaloreParamState::LowRank {
                    last_refresh: 0,
                    side: 0,
                    // The stored representation rides through the canonical
                    // form untouched — use a quantized P to pin that.
                    p: StoredTensor::Q8 {
                        rows: 5,
                        cols: r,
                        q: crate::quant::LinearQ8::quantize(
                            &(0..5 * r).map(|k| k as f32 * 0.1).collect::<Vec<_>>(),
                        ),
                    },
                    m: Vec::new(), // lazily unsized: preset but never stepped
                    v: Vec::new(),
                },
            ),
        ];
        let mut rng_bytes = Vec::new();
        Pcg64::new(11, 0x6a10).write_state(&mut rng_bytes);
        let blob = write_galore(&GaloreBlob {
            t: 9,
            refreshes: 4,
            rng: rng_bytes,
            states,
        });
        for world in [1usize, 2, 3, 4, 5] {
            let frames = scatter_galore(&blob, world, &ms).unwrap();
            assert_eq!(frames.len(), world);
            let back = gather_galore(&frames, &ms).unwrap();
            assert_eq!(back, blob, "world {world}: scatter/gather not identity");
        }
    }

    #[test]
    fn corrupt_counts_error_instead_of_aborting() {
        // Bit-flipped counts must yield Err, not a capacity-overflow
        // abort that bypasses the loud-failure contract.
        let mut blob = Vec::new();
        blob.extend_from_slice(MAGIC);
        push_u64(&mut blob, 6);
        blob.extend_from_slice(b"galore");
        push_u64(&mut blob, FLAVOR_PER_RANK);
        push_u64(&mut blob, u64::MAX); // insane frame count
        assert!(CanonicalOptState::decode(&blob).is_err());

        let mut g = Vec::new();
        push_u64(&mut g, 0); // t
        push_u64(&mut g, 0); // refreshes
        Pcg64::new(0, 0).write_state(&mut g);
        push_u64(&mut g, u64::MAX); // insane state count
        assert!(parse_galore(&g).is_err());

        let mut m = Vec::new();
        push_u64(&mut m, 0); // t
        push_u64(&mut m, u64::MAX); // insane state count
        assert!(parse_moments(&m, 2).is_err());
    }

    #[test]
    fn codec_conversion_bridges_raw_and_framed_qgalore_layouts() {
        // The "qgalore" name covers two layouts (OptimizerSpec::state_codec):
        // a concrete GaLore exporting the raw layout must still produce a
        // framed canonical blob, and imports convert back per target codec.
        let o = ImportOpts::default();
        let raw = vec![7u8; 40];
        let c = CanonicalOptState::from_full("qgalore", "galore", raw.clone()).unwrap();
        assert_eq!(
            c.to_full_for("galore", &[], o).unwrap(),
            raw,
            "raw → framed → raw"
        );
        assert_eq!(
            c.to_full_for("qgalore", &[], o).unwrap(),
            wrap_qgalore(raw.clone()),
            "framed view keeps the canonical layout"
        );
        // A true QGaLore blob passes through unchanged for its own codec.
        let framed = wrap_qgalore(raw.clone());
        let c = CanonicalOptState::from_full("qgalore", "qgalore", framed.clone()).unwrap();
        assert_eq!(c.to_full_for("qgalore", &[], o).unwrap(), framed);
        assert_eq!(c.to_full_for("galore", &[], o).unwrap(), raw);
        // Non-family names are untouched by codec conversion.
        let c = CanonicalOptState::from_full("adamw", "adamw", raw.clone()).unwrap();
        assert_eq!(c.to_full_for("adamw", &[], o).unwrap(), raw);
    }

    #[test]
    fn qgalore_framing_roundtrips() {
        let inner = vec![5u8; 24];
        let wrapped = wrap_qgalore(inner.clone());
        assert_eq!(unwrap_qgalore(&wrapped).unwrap(), inner);
        assert!(unwrap_qgalore(&wrapped[..10]).is_err());
    }

    #[test]
    fn per_rank_world_mismatch_errors_are_actionable() {
        let o = ImportOpts::default();
        let c = CanonicalOptState {
            name: "adam8bit".into(),
            payload: OptPayload::PerRank {
                frames: vec![vec![0; 40]; 2],
            },
        };
        let err = c.fsdp_frames(4, &[], o).unwrap_err();
        assert!(
            err.contains("world=2")
                && err.contains("adam8bit")
                && err.contains("--resume-requantize"),
            "unhelpful error: {err}"
        );
        let err = c.to_full(&[], o).unwrap_err();
        assert!(
            err.contains("world-locked") && err.contains("--resume-requantize"),
            "unhelpful error: {err}"
        );
        // A non-convertible optimizer's error names the re-shardable set
        // instead of the opt-in flag.
        let sgd_like = CanonicalOptState {
            name: "mystery".into(),
            payload: OptPayload::PerRank {
                frames: vec![vec![0; 40]; 2],
            },
        };
        let err = sgd_like.fsdp_frames(4, &[], o).unwrap_err();
        assert!(err.contains("galore"), "unhelpful error: {err}");
        // Same-world passthrough still works.
        assert_eq!(c.fsdp_frames(2, &[], o).unwrap().len(), 2);
    }

    #[test]
    fn non_reshardable_full_state_only_fits_world_one() {
        let o = ImportOpts::default();
        let c = CanonicalOptState::full("adafactor", vec![3; 50]);
        let frames = c.fsdp_frames(1, &[], o).unwrap();
        assert_eq!(frames.len(), 1);
        assert_eq!(&frames[0][Pcg64::STATE_BYTES..], &[3u8; 50][..]);
        let err = c.fsdp_frames(2, &[], o).unwrap_err();
        assert!(err.contains("adafactor"), "unhelpful error: {err}");
    }

    // -- quantized canonical state ----------------------------------------

    fn meta(name: &str, rows: usize, cols: usize) -> ParamMeta {
        ParamMeta {
            name: name.into(),
            rows,
            cols,
        }
    }

    #[test]
    fn block_alignment_predicate_matches_geometry() {
        // (512, 2) shards rows: world 2 and 4 land every boundary on a
        // multiple of 256 flat elements; world 3 does not (170·2 = 340).
        let tall = meta("tall", 512, 2);
        assert!(shards_block_aligned(&tall, 1));
        assert!(shards_block_aligned(&tall, 2));
        assert!(shards_block_aligned(&tall, 4));
        assert!(!shards_block_aligned(&tall, 3));
        // (2, 1024) shards cols: per-row runs start at r·1024 + lo, all
        // multiples of 256 for world 2/4; world 8 slices 128-wide.
        let wide = meta("wide", 2, 1024);
        assert!(shards_block_aligned(&wide, 2));
        assert!(shards_block_aligned(&wide, 4));
        assert!(!shards_block_aligned(&wide, 8));
        // Small tensors only align at world 1 (single partial block).
        let small = meta("small", 8, 16);
        assert!(shards_block_aligned(&small, 1));
        assert!(!shards_block_aligned(&small, 2));
    }

    #[test]
    fn q8_slice_concat_roundtrip_on_aligned_geometry() {
        let mut rng = Pcg64::new(31, 0);
        for (rows, cols) in [(512usize, 2usize), (2, 1024), (1024, 1)] {
            let m = meta("p", rows, cols);
            let mut xs = vec![0f32; rows * cols];
            rng.fill_normal(&mut xs, 1.0);
            let full = Quantized8::quantize(&xs);
            for world in [1usize, 2, 4] {
                assert!(shards_block_aligned(&m, world), "{rows}x{cols} w{world}");
                let parts: Vec<Quantized8> = (0..world)
                    .map(|rank| slice_q8(&full, &m, world, rank).unwrap())
                    .collect();
                // Each slice is exactly what quantizing the shard directly
                // would produce — the FSDP worker's own state.
                for (rank, part) in parts.iter().enumerate() {
                    let axis = shard_axis(rows, cols);
                    let shard = slice_vec(&xs, rows, cols, axis, world, rank);
                    assert_eq!(part, &Quantized8::quantize(&shard), "rank {rank}");
                }
                let refs: Vec<&Quantized8> = parts.iter().collect();
                assert_eq!(
                    concat_q8(&refs, &m).unwrap(),
                    full,
                    "{rows}x{cols} world {world}: slice∘concat not identity"
                );
            }
        }
    }

    #[test]
    fn adam8bit_scatter_requires_opt_in_when_misaligned() {
        let metas = vec![meta("p0", 8, 16)];
        let xs: Vec<f32> = (0..128).map(|k| k as f32 * 0.01).collect();
        let states: QuantStates = vec![(
            0,
            vec![
                CanonicalTensor::Q8(Quantized8::quantize(&xs)),
                CanonicalTensor::Q8(Quantized8::quantize(&xs)),
            ],
        )];
        let err =
            scatter_adam8bit(3, &states, 2, &metas, ImportOpts::default()).unwrap_err();
        assert!(err.contains("--resume-requantize"), "unhelpful error: {err}");
        let frames = scatter_adam8bit(3, &states, 2, &metas, ImportOpts::requantize()).unwrap();
        assert_eq!(frames.len(), 2);
        // World 1 is always exact: scatter then re-parse reproduces the
        // canonical tensors bit-for-bit.
        let frames = scatter_adam8bit(3, &states, 1, &metas, ImportOpts::default()).unwrap();
        let (t, back) = parse_adam8bit(&frames[0][Pcg64::STATE_BYTES..]).unwrap();
        assert_eq!(t, 3);
        assert_eq!(back, states);
    }

    #[test]
    fn adafactor_roundtrip_and_cross_world_conversions() {
        // parse∘write is the identity on the adafactor layout; scatter at
        // world 1 is exact; wider worlds need the opt-in and slice the
        // shard-axis factor exactly while replicating the cross factor.
        let metas = vec![meta("p0", 6, 3), meta("p1", 2, 8)];
        let mut blob = Vec::new();
        push_u64(&mut blob, 9); // t
        push_u64(&mut blob, 2); // two states
        push_u64(&mut blob, 0);
        push_f32s(&mut blob, &(0..6).map(|k| k as f32 + 0.5).collect::<Vec<_>>());
        push_f32s(&mut blob, &(0..3).map(|k| k as f32 + 0.25).collect::<Vec<_>>());
        push_u64(&mut blob, 1);
        push_f32s(&mut blob, &[1.5, 2.5]);
        push_f32s(&mut blob, &(0..8).map(|k| k as f32).collect::<Vec<_>>());
        let (t, states) = parse_adafactor(&blob).unwrap();
        assert_eq!(t, 9);
        assert_eq!(write_adafactor(t, &states).unwrap(), blob, "parse∘write");

        let err =
            scatter_adafactor(t, &states, 2, &metas, ImportOpts::default()).unwrap_err();
        assert!(err.contains("--resume-requantize"), "unhelpful error: {err}");
        let frames = scatter_adafactor(t, &states, 2, &metas, ImportOpts::requantize()).unwrap();
        assert_eq!(frames.len(), 2);
        // p0 (6x3) shards rows: rank 0 gets rows 0..3 of the row factor
        // and the FULL col factor.
        let (_, rank0) = parse_adafactor(&frames[0][Pcg64::STATE_BYTES..]).unwrap();
        assert_eq!(
            rank0[0].1,
            vec![
                CanonicalTensor::F32(vec![0.5, 1.5, 2.5]),
                CanonicalTensor::F32(vec![0.25, 1.25, 2.25]),
            ]
        );
        // Merging the sliced frames back recovers the original factors
        // exactly: slicing is exact along the shard axis, and the
        // replicated cross factors weight-average back to themselves.
        let (mt, merged) = merge_adafactor_frames(&frames, &metas).unwrap();
        assert_eq!(mt, t);
        for ((ia, a), (ib, b)) in merged.iter().zip(&states) {
            assert_eq!(ia, ib);
            for (ta, tb) in a.iter().zip(b) {
                let (va, vb) = (ta.values(), tb.values());
                assert_eq!(va.len(), vb.len());
                for (x, y) in va.iter().zip(&vb) {
                    assert!((x - y).abs() < 1e-6, "merged factor drifted: {x} vs {y}");
                }
            }
        }
    }
}
