//! Downstream evaluation harness (§6, Fig. 4, Tables 3–7).
//!
//! Five task categories mirror the paper's grouping; each task is a
//! k-way multiple-choice question over the synthetic corpus's latent
//! Markov structure, scored by the model's next-token log-probability
//! (the same protocol lm-eval-harness uses for its MC suites). Few-shot
//! context is provided by prepending real corpus windows — the analogue
//! of the paper's 5-shot demonstrations.
//!
//! Ground truth comes from the generator itself (`Corpus::successor`), so
//! accuracy genuinely measures how much of the corpus's conditional
//! structure the model internalized — a better-trained LM scores higher,
//! and the GaLore-vs-baseline *delta* is the reproduced quantity.

use crate::data::Corpus;
use crate::runtime::{Executable, HostTensor, Manifest};
use crate::tensor::Matrix;
use crate::util::rng::Pcg64;
use anyhow::Result;
use std::sync::Arc;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Category {
    LanguageUnderstanding,
    Commonsense,
    Paraphrase,
    Truthfulness,
    AcademicExams,
}

impl Category {
    pub const ALL: [Category; 5] = [
        Category::LanguageUnderstanding,
        Category::Commonsense,
        Category::Paraphrase,
        Category::Truthfulness,
        Category::AcademicExams,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Category::LanguageUnderstanding => "language_understanding",
            Category::Commonsense => "commonsense",
            Category::Paraphrase => "paraphrase",
            Category::Truthfulness => "truthfulness",
            Category::AcademicExams => "academic_exams",
        }
    }

    fn n_options(&self) -> usize {
        match self {
            Category::AcademicExams => 8,
            Category::Paraphrase => 2,
            _ => 4,
        }
    }
}

/// One MC question: a context window and candidate next tokens.
#[derive(Clone, Debug)]
pub struct Question {
    pub context: Vec<u32>,
    pub options: Vec<u32>,
    pub answer: usize,
}

#[derive(Clone, Debug)]
pub struct CategoryResult {
    pub category: Category,
    pub accuracy: f64,
    pub n: usize,
    pub chance: f64,
}

/// Builds and scores the synthetic five-category suite.
pub struct EvalHarness {
    forward: Arc<Executable>,
    manifest: Manifest,
    corpus: Corpus,
}

impl EvalHarness {
    pub fn new(forward: Arc<Executable>, manifest: Manifest, corpus: Corpus) -> EvalHarness {
        EvalHarness {
            forward,
            manifest,
            corpus,
        }
    }

    /// Generate `n` questions for a category. Deterministic per (category,
    /// seed): GaLore and baseline checkpoints see identical questions.
    pub fn questions(&self, category: Category, n: usize, seed: u64) -> Vec<Question> {
        let mut rng = Pcg64::new(seed ^ category.name().len() as u64, 0xe7a1);
        let vocab = self.corpus.cfg.vocab as u64;
        let seq = self.manifest.seq;
        let k = category.n_options();
        // Few-shot prelude: 5 demonstration windows from an eval-only
        // stream (stream ids ≥ 2 never touch train/val data).
        let shots = self.corpus.sample(seq.saturating_sub(8).max(2), 7);
        (0..n)
            .map(|qi| {
                let mut ctx = shots.clone();
                // Question context difficulty is controlled by how often the
                // context token appears in training: common contexts (chain
                // walk → stationary distribution) are easy; tail tokens are
                // undersampled and genuinely hard. Mix per category so
                // accuracies land between chance and ceiling, like the
                // paper's mid-range scores.
                let mut a = rng.next_below(vocab) as u32;
                let mut b;
                let hard = matches!(
                    category,
                    Category::Truthfulness | Category::AcademicExams
                ) || qi % 2 == 1;
                if hard {
                    // Rare tail: ids in the upper half of the Zipf-ish
                    // marginal (see Corpus::successor's u² mapping).
                    b = (vocab / 2 + rng.next_below(vocab / 2)) as u32;
                } else {
                    b = rng.next_below(vocab) as u32;
                    for _ in 0..3 {
                        let next = self.corpus.successor(a, b, 0);
                        a = b;
                        b = next;
                    }
                }
                ctx.push(a);
                ctx.push(b);
                if ctx.len() > seq {
                    let cut = ctx.len() - seq;
                    ctx.drain(..cut);
                }
                let truth = self.corpus.best_successor(a, b);
                let mut options = vec![truth];
                match category {
                    Category::Paraphrase => {
                        // Distractor: best successor of an unrelated context
                        // (tests whether the model binds continuations to
                        // *this* context — semantic-equivalence analogue).
                        let mut other = self
                            .corpus
                            .best_successor(b, a.wrapping_add(1 + qi as u32) % vocab as u32);
                        if other == truth {
                            other = (other + 1) % vocab as u32;
                        }
                        options.push(other);
                    }
                    Category::Truthfulness => {
                        // Distractors: low-probability successors of the
                        // SAME context (plausible but "untrue" tails).
                        for k_i in
                            [self.corpus.cfg.branching - 1, self.corpus.cfg.branching - 2]
                        {
                            let mut o = self.corpus.successor(a, b, k_i);
                            while options.contains(&o) {
                                o = (o + 1) % vocab as u32;
                            }
                            options.push(o);
                        }
                        let mut o = rng.next_below(vocab) as u32;
                        while options.contains(&o) {
                            o = (o + 1) % vocab as u32;
                        }
                        options.push(o);
                    }
                    Category::AcademicExams => {
                        // Hardest: distractors are valid successors of the
                        // SAME context (k = 1..) — only relative frequency
                        // separates them — padded with other-context
                        // successors (plausible tokens).
                        let mut k_i = 1;
                        while options.len() < k && k_i < self.corpus.cfg.branching {
                            let o = self.corpus.successor(a, b, k_i);
                            if !options.contains(&o) {
                                options.push(o);
                            }
                            k_i += 1;
                        }
                        while options.len() < k {
                            let alt = rng.next_below(vocab) as u32;
                            let o = self.corpus.best_successor(b, alt);
                            if !options.contains(&o) {
                                options.push(o);
                            } else {
                                let f = (o + 1 + options.len() as u32) % vocab as u32;
                                if !options.contains(&f) {
                                    options.push(f);
                                }
                            }
                        }
                    }
                    _ => {
                        // Random-token distractors.
                        while options.len() < k {
                            let mut o = rng.next_below(vocab) as u32;
                            while options.contains(&o) {
                                o = (o + 1) % vocab as u32;
                            }
                            options.push(o);
                        }
                    }
                }
                // Shuffle options, remember the answer slot.
                let mut order: Vec<usize> = (0..options.len()).collect();
                rng.shuffle(&mut order);
                let shuffled: Vec<u32> = order.iter().map(|&i| options[i]).collect();
                let answer = order.iter().position(|&i| i == 0).unwrap();
                Question {
                    context: ctx,
                    options: shuffled,
                    answer,
                }
            })
            .collect()
    }

    /// Log-probabilities of each option as the next token after `context`.
    /// Executes the forward artifact on (batch) questions at a time.
    fn score_batch(&self, params: &[Matrix], questions: &[Question]) -> Result<Vec<usize>> {
        let (batch, seq, vocab) = (self.manifest.batch, self.manifest.seq, self.manifest.vocab);
        let mut picks = Vec::with_capacity(questions.len());
        for chunk in questions.chunks(batch) {
            let mut tokens = vec![0i32; batch * seq];
            let mut ctx_last = vec![0usize; batch];
            for (row, q) in chunk.iter().enumerate() {
                let start = seq - q.context.len().min(seq);
                for (i, &t) in q.context.iter().rev().take(seq).rev().enumerate() {
                    tokens[row * seq + start + i] = t as i32;
                }
                ctx_last[row] = seq - 1; // context right-aligned
            }
            let mut inputs: Vec<HostTensor> = self
                .manifest
                .params
                .iter()
                .zip(params)
                .map(|(spec, m)| {
                    if spec.shape.len() == 1 {
                        HostTensor::from_vec1(&m.data)
                    } else {
                        HostTensor::from_matrix(m)
                    }
                })
                .collect();
            inputs.push(HostTensor::tokens(&tokens, batch, seq));
            let out = self.forward.run(&inputs)?;
            let logits = &out[0]; // (batch, seq, vocab)
            for (row, q) in chunk.iter().enumerate() {
                let base = (row * seq + ctx_last[row]) * vocab;
                let row_logits = &logits[base..base + vocab];
                // log-softmax denominator is shared: argmax over raw logits.
                let pick = q
                    .options
                    .iter()
                    .enumerate()
                    .max_by(|(_, &a), (_, &b)| {
                        row_logits[a as usize]
                            .partial_cmp(&row_logits[b as usize])
                            .unwrap()
                    })
                    .map(|(i, _)| i)
                    .unwrap();
                picks.push(pick);
            }
        }
        Ok(picks)
    }

    /// Run one category: accuracy over `n` questions.
    pub fn run_category(
        &self,
        params: &[Matrix],
        category: Category,
        n: usize,
        seed: u64,
    ) -> Result<CategoryResult> {
        let questions = self.questions(category, n, seed);
        let picks = self.score_batch(params, &questions)?;
        let correct = picks
            .iter()
            .zip(&questions)
            .filter(|(&p, q)| p == q.answer)
            .count();
        Ok(CategoryResult {
            category,
            accuracy: correct as f64 / n as f64,
            n,
            chance: 1.0 / category.n_options() as f64,
        })
    }

    /// The full five-category suite (Tables 3–7 / Fig. 4).
    pub fn run_suite(
        &self,
        params: &[Matrix],
        per_category: usize,
        seed: u64,
    ) -> Result<Vec<CategoryResult>> {
        Category::ALL
            .iter()
            .map(|&c| self.run_category(params, c, per_category, seed))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::CorpusCfg;

    fn corpus() -> Corpus {
        Corpus::new(CorpusCfg {
            vocab: 256,
            branching: 8,
            order: 1,
            seed: 0xc0de ^ 42,
        })
    }

    fn harness() -> Option<EvalHarness> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let mp = dir.join("manifest_llama-nano.json");
        if !mp.exists() {
            return None;
        }
        let manifest = Manifest::load(mp).unwrap();
        let rt = crate::runtime::Runtime::cpu().unwrap();
        let fwd = rt.load(dir.join(&manifest.artifacts["forward"])).unwrap();
        Some(EvalHarness::new(fwd, manifest, corpus()))
    }

    #[test]
    fn questions_deterministic_and_well_formed() {
        let Some(h) = harness() else { return };
        for cat in Category::ALL {
            let qs1 = h.questions(cat, 12, 9);
            let qs2 = h.questions(cat, 12, 9);
            assert_eq!(qs1.len(), 12);
            for (a, b) in qs1.iter().zip(&qs2) {
                assert_eq!(a.options, b.options);
                assert_eq!(a.answer, b.answer);
            }
            for q in &qs1 {
                assert!(q.answer < q.options.len());
                assert_eq!(q.options.len(), cat.n_options());
                // options distinct
                let mut o = q.options.clone();
                o.sort_unstable();
                o.dedup();
                assert_eq!(o.len(), q.options.len());
                assert!(q.context.len() <= h.manifest.seq);
            }
        }
    }

    #[test]
    fn untrained_model_scores_near_chance() {
        let Some(h) = harness() else { return };
        let cfg = crate::model::LlamaCfg::preset("llama-nano").unwrap();
        let params = crate::model::init_params(&cfg, 3);
        let res = h
            .run_category(&params, Category::LanguageUnderstanding, 24, 5)
            .unwrap();
        assert_eq!(res.n, 24);
        // Untrained: accuracy within a wide band around chance (0.25).
        assert!(
            res.accuracy < 0.7,
            "untrained model suspiciously good: {}",
            res.accuracy
        );
    }

    #[test]
    fn suite_covers_all_categories() {
        let Some(h) = harness() else { return };
        let cfg = crate::model::LlamaCfg::preset("llama-nano").unwrap();
        let params = crate::model::init_params(&cfg, 4);
        let results = h.run_suite(&params, 8, 1).unwrap();
        assert_eq!(results.len(), 5);
        let cats: Vec<_> = results.iter().map(|r| r.category).collect();
        for c in Category::ALL {
            assert!(cats.contains(&c));
        }
    }
}
