//! The trainer: config → artifacts → data → step loop → events.
//!
//! Per step:
//!   1. draw one packed microbatch per rank (`engine.world()` of them),
//!   2. execute the fwd_bwd artifact per microbatch (loss + grads),
//!   3. hand the per-rank gradients to the [`TrainEngine`], which owns the
//!      parameters and optimizer state for its execution mode (single
//!      process, FSDP-sharded, or DDP-replicated — see train/engine.rs),
//!   4. emit [`StepEvent`]s; periodically sweep validation and checkpoint.
//!
//! The optimizer itself is always built from `cfg.optimizer_spec()` via
//! [`crate::optim::OptimizerSpec::build`] — the trainer contains no
//! optimizer construction logic of its own.
//!
//! Parallel execution: `cfg.threads` sets the process-wide worker-pool
//! default (`crate::parallel`), so per-layer optimizer stepping fans its
//! projection/reprojection GEMMs and SVD refreshes across cores; under
//! FSDP/DDP the per-layer loop itself additionally runs concurrently
//! across the cluster's worker threads. Both layers of parallelism are
//! bitwise deterministic (fixed-tree reductions, panel-local kernels).

use crate::checkpoint::Checkpoint;
use crate::config::{Engine, ParallelMode, TrainConfig};
use crate::data::{Batch, Corpus, CorpusCfg, DataLoader};
use crate::dist::{MemoryReport, ParamMeta, PjrtResources};
use crate::metrics::Metrics;
use crate::model::LlamaCfg;
use crate::optim::lr::Schedule;
use crate::runtime::{Executable, HostTensor, Manifest, Runtime};
use crate::tensor::Matrix;
use crate::train::{
    DdpEngine, EngineFactory, FsdpEngine, RecoveryPolicy, SingleEngine, StepEvent,
    StepObserver, Supervised, Supervisor, TrainEngine,
};
use crate::util::Timer;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

pub struct Trainer {
    pub cfg: TrainConfig,
    pub llama: LlamaCfg,
    pub manifest: Manifest,
    rt: Arc<Runtime>,
    fwd_bwd: Arc<Executable>,
    pub loader: DataLoader,
    pub schedule: Schedule,
    pub metrics: Metrics,
    /// Owns the engine; converts worker deaths into snapshot-restore
    /// cycles per `--on-failure` (train/supervisor.rs).
    supervisor: Supervisor,
    observers: Vec<Box<dyn StepObserver>>,
    pub tokens_seen: u64,
    start_step: u64,
    wall: Timer,
}

/// Summary of a finished run.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub final_train_loss: f64,
    pub final_val_loss: f64,
    pub tokens: u64,
    pub steps: u64,
    pub wall_secs: f64,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Result<Trainer> {
        // Cross-field validation first — a bad flag combination must not
        // cost an artifact load or corpus synthesis before erroring.
        cfg.validate()?;
        // Pin the compute pool before any kernel runs; 0 keeps auto-detect.
        crate::parallel::set_default_threads(cfg.threads);
        // `--pool false` routes kernels through the scoped per-call
        // spawner instead of the persistent pool (bitwise identical).
        crate::parallel::set_pool_enabled(cfg.pool);
        // Spawn/handshake retry budget for the process transport
        // (`[dist] spawn_retries` / `--spawn-retries`).
        crate::dist::set_spawn_retries(cfg.spawn_retries);
        // `--overlap false` keeps every collective inline on the worker
        // (the serial bitwise reference); default pipelines per-layer
        // reduces behind optimizer compute (bitwise identical).
        crate::dist::set_overlap_enabled(cfg.overlap);
        // `--shm false` keeps process-transport payloads on the comm
        // sockets; default moves them through the shared slot table
        // (bitwise identical — the data plane never reorders the tree).
        crate::dist::set_shm_enabled(cfg.shm);
        let llama = LlamaCfg::preset(&cfg.preset)
            .with_context(|| format!("unknown preset {:?}", cfg.preset))?;
        let manifest = Manifest::load(
            cfg.artifacts_dir
                .join(format!("manifest_{}.json", cfg.preset)),
        )
        .with_context(|| {
            format!(
                "manifest for {} missing — run `make artifacts PRESET={}`",
                cfg.preset, cfg.preset
            )
        })?;
        let rt = Arc::new(Runtime::cpu()?);
        let fwd_bwd = rt.load(
            cfg.artifacts_dir
                .join(&manifest.artifacts["fwd_bwd"]),
        )?;

        let corpus = Corpus::new(CorpusCfg {
            vocab: llama.vocab,
            branching: 8,
            order: 1,
            seed: cfg.seed ^ 0xc0de,
        });
        let loader = DataLoader::new(
            &corpus,
            cfg.corpus_tokens,
            cfg.val_tokens,
            llama.batch,
            llama.seq,
            cfg.seed,
        );

        let params = crate::model::init_params(&llama, cfg.seed);
        let schedule = Schedule::WarmupCosine {
            peak: cfg.lr,
            warmup: ((cfg.steps as f64 * cfg.warmup_frac) as u64).max(1),
            total: cfg.steps,
            floor_frac: cfg.lr_floor_frac,
        };

        // THE optimizer construction path: every mode builds from the spec.
        let spec = cfg.optimizer_spec(llama.hidden)?;
        let metas: Vec<ParamMeta> = manifest
            .params
            .iter()
            .map(|p| {
                let (rows, cols) = p.matrix_shape();
                ParamMeta {
                    name: p.name.clone(),
                    rows,
                    cols,
                }
            })
            .collect();
        // Build the engine AND a factory that can rebuild it at any world
        // size after a worker death — the supervisor's recovery path
        // re-installs the snapshot into the factory's product, so the
        // init params passed here are placeholders of the right shapes.
        let seed = cfg.seed;
        let transport = cfg.transport;
        let (engine, factory): (Box<dyn TrainEngine>, EngineFactory) = match cfg.parallel {
            ParallelMode::Single => {
                let pjrt = if cfg.engine == Engine::Pjrt {
                    Some(PjrtResources {
                        rt: rt.clone(),
                        artifacts_dir: cfg.artifacts_dir.clone(),
                        manifest: manifest.clone(),
                    })
                } else {
                    None
                };
                let engine: Box<dyn TrainEngine> = Box::new(
                    SingleEngine::new(&spec, cfg.seed, pjrt.as_ref(), params)
                        .map_err(anyhow::Error::msg)?,
                );
                // No worker fabric to rebuild; validate() rejects
                // --on-failure respawn|shrink for single mode, so this
                // factory can only be reached by a bug.
                let factory: EngineFactory = Box::new(|_| {
                    Err("single-process engine cannot be rebuilt".to_string())
                });
                (engine, factory)
            }
            ParallelMode::Fsdp => {
                let engine: Box<dyn TrainEngine> = Box::new(
                    FsdpEngine::with_transport(
                        cfg.world.max(1),
                        metas.clone(),
                        spec.clone(),
                        seed,
                        &params,
                        transport,
                    )
                    .map_err(anyhow::Error::msg)?,
                );
                let factory: EngineFactory = Box::new(move |world| {
                    FsdpEngine::with_transport(
                        world,
                        metas.clone(),
                        spec.clone(),
                        seed,
                        &params,
                        transport,
                    )
                    .map(|e| Box::new(e) as Box<dyn TrainEngine>)
                });
                (engine, factory)
            }
            ParallelMode::Ddp => {
                let engine: Box<dyn TrainEngine> = Box::new(
                    DdpEngine::with_transport(
                        cfg.world.max(1),
                        metas.clone(),
                        spec.clone(),
                        seed,
                        &params,
                        transport,
                    )
                    .map_err(anyhow::Error::msg)?,
                );
                let factory: EngineFactory = Box::new(move |world| {
                    DdpEngine::with_transport(
                        world,
                        metas.clone(),
                        spec.clone(),
                        seed,
                        &params,
                        transport,
                    )
                    .map(|e| Box::new(e) as Box<dyn TrainEngine>)
                });
                (engine, factory)
            }
        };
        let supervisor = Supervisor::new(
            engine,
            factory,
            RecoveryPolicy {
                on_failure: cfg.on_failure,
                snapshot_every: cfg.snapshot_every,
                max_recoveries: cfg.max_recoveries,
            },
            crate::train::ImportOpts {
                requantize: cfg.resume_requantize,
            },
        );

        Ok(Trainer {
            cfg,
            llama,
            manifest,
            rt,
            fwd_bwd,
            loader,
            schedule,
            metrics: Metrics::new(),
            supervisor,
            observers: Vec::new(),
            tokens_seen: 0,
            start_step: 0,
            wall: Timer::start(),
        })
    }

    /// Current full parameters (the engine's authoritative view).
    pub fn params(&self) -> &[Matrix] {
        self.supervisor.engine().params()
    }

    /// The execution engine (mode name, world size, telemetry).
    pub fn engine(&self) -> &dyn TrainEngine {
        self.supervisor.engine()
    }

    /// The fault-tolerance supervisor (recovery count, snapshot step).
    pub fn supervisor(&self) -> &Supervisor {
        &self.supervisor
    }

    /// Subscribe to the trainer's [`StepEvent`] stream. [`Metrics`] is
    /// always subscribed; external observers see the same events.
    pub fn add_observer(&mut self, observer: Box<dyn StepObserver>) {
        self.observers.push(observer);
    }

    fn emit(&mut self, event: StepEvent) {
        self.metrics.on_event(&event);
        for obs in &mut self.observers {
            obs.on_event(&event);
        }
    }

    /// Inputs for one execution: params (in ABI shapes) + tokens + targets.
    fn build_inputs(&self, batch: &Batch) -> Vec<HostTensor> {
        let mut inputs: Vec<HostTensor> = self
            .manifest
            .params
            .iter()
            .zip(self.supervisor.engine().params())
            .map(|(spec, m)| {
                if spec.shape.len() == 1 {
                    HostTensor::from_vec1(&m.data)
                } else {
                    HostTensor::from_matrix(m)
                }
            })
            .collect();
        inputs.push(HostTensor::tokens(&batch.tokens, batch.batch, batch.seq));
        inputs.push(HostTensor::tokens(&batch.targets, batch.batch, batch.seq));
        inputs
    }

    /// Execute fwd_bwd on a batch: (loss, grads as matrices).
    fn compute_grads(&self, batch: &Batch) -> Result<(f32, Vec<Matrix>)> {
        let out = self.fwd_bwd.run(&self.build_inputs(batch))?;
        let loss = out[0][0];
        let grads = self
            .manifest
            .params
            .iter()
            .zip(out.into_iter().skip(1))
            .map(|(spec, data)| {
                let (r, c) = spec.matrix_shape();
                Matrix::from_vec(r, c, data)
            })
            .collect();
        Ok((loss, grads))
    }

    /// Draw step `t`'s per-rank microbatches and run fwd_bwd on each:
    /// (lr, per-microbatch losses, per-rank grads). Increments
    /// `tokens_seen` — a recovery rewinds the counter via the snapshot.
    fn step_inputs(&mut self, t: u64) -> Result<(f32, Vec<f32>, Vec<Vec<Matrix>>)> {
        let lr = self.schedule.lr(t);
        let world = self.supervisor.engine().world();
        let batches = self.loader.train_microbatches_at(t, world);
        let mut losses = Vec::with_capacity(world);
        let mut per_rank = Vec::with_capacity(world);
        for b in &batches {
            self.tokens_seen += (b.batch * b.seq) as u64;
            let (l, g) = self.compute_grads(b)?;
            losses.push(l);
            per_rank.push(g);
        }
        Ok((lr, losses, per_rank))
    }

    /// One optimizer step; returns the mean training loss over this step's
    /// per-rank microbatches (one microbatch for single-process engines).
    /// Panics on worker death — the supervised path lives in [`Trainer::run`].
    pub fn train_step(&mut self, t: u64) -> Result<f32> {
        let (lr, losses, per_rank) = self.step_inputs(t)?;
        let world = losses.len().max(1);
        self.supervisor.engine_mut().step(t, per_rank, lr);
        Ok(losses.iter().sum::<f32>() / world as f32)
    }

    /// Mean validation loss over `batches` deterministic windows.
    pub fn validate(&mut self, batches: usize) -> Result<f64> {
        self.loader.reset_val();
        let mut total = 0f64;
        for _ in 0..batches.max(1) {
            let batch = self.loader.next_val();
            let (loss, _) = self.compute_grads(&batch)?;
            total += loss as f64;
        }
        Ok(total / batches.max(1) as f64)
    }

    /// Full training run with event emission / eval / checkpoints.
    ///
    /// Fault tolerance: under `--on-failure respawn|shrink` the loop
    /// captures a rolling in-memory snapshot every
    /// `[train] snapshot_every` steps, and a worker death mid-step
    /// rewinds to that snapshot on a freshly rebuilt cluster instead of
    /// crashing the run (see train/supervisor.rs).
    pub fn run(&mut self) -> Result<TrainOutcome> {
        let steps = self.cfg.steps;
        let mut last_train = f64::NAN;
        let mut last_val: Option<(u64, f64)> = None;
        let mut t = self.start_step;
        while t < steps {
            // BEFORE the microbatches are drawn: the snapshot's
            // step/tokens_seen mean "step t has not run yet".
            self.supervisor.maybe_snapshot(t, self.tokens_seen);
            let (lr, losses, per_rank) = self.step_inputs(t)?;
            match self
                .supervisor
                .step(t, per_rank, lr)
                .map_err(anyhow::Error::msg)?
            {
                Supervised::Recovered {
                    resume_step,
                    tokens_seen,
                    events,
                    ..
                } => {
                    for e in events {
                        self.emit(e);
                    }
                    self.tokens_seen = tokens_seen;
                    t = resume_step;
                    continue;
                }
                Supervised::Stepped => {}
            }
            // Per-step firehose (every step, not log_every): the slowest
            // rank's comm/compute split, straight from the cluster —
            // benches subscribe here instead of timing around step().
            if let Some(timing) = self.supervisor.engine().last_step_timing() {
                self.emit(StepEvent::StepTimed {
                    step: t,
                    comm_ns: timing.comm_ns,
                    compute_ns: timing.compute_ns,
                });
            }
            if let Some(traffic) = self.supervisor.engine().last_step_traffic() {
                self.emit(StepEvent::StepTraffic {
                    step: t,
                    socket_bytes: traffic.socket_bytes,
                    shm_bytes: traffic.shm_bytes,
                    peak_transient: traffic.peak_transient_bytes,
                });
            }
            let loss = (losses.iter().sum::<f32>() / losses.len().max(1) as f32) as f64;
            last_train = loss;
            if t % self.cfg.log_every == 0 || t + 1 == steps {
                self.emit(StepEvent::Train {
                    step: t,
                    loss,
                    lr: self.schedule.lr(t) as f64,
                    tokens_seen: self.tokens_seen,
                    wall_secs: self.wall.elapsed_secs(),
                });
            }
            if self.cfg.eval_every > 0
                && (t % self.cfg.eval_every == 0 || t + 1 == steps)
            {
                let val = self.validate(self.cfg.eval_batches)?;
                last_val = Some((t, val));
                self.emit(StepEvent::Val {
                    step: t,
                    loss: val,
                    lr: self.schedule.lr(t) as f64,
                    tokens_seen: self.tokens_seen,
                    wall_secs: self.wall.elapsed_secs(),
                });
            }
            if self.cfg.checkpoint_every > 0
                && t > 0
                && t % self.cfg.checkpoint_every == 0
            {
                // Label = completed-step count = the step a resume runs
                // next (ckpt.step convention of Trainer::resume); saving
                // with label t would make the resumed run re-apply step t
                // to optimizer state that already consumed it.
                let path = self.save_checkpoint(t + 1)?;
                self.emit(StepEvent::Checkpoint { step: t + 1, path });
            }
            t += 1;
        }
        // The eval cadence already sweeps validation on the final step;
        // reuse it rather than paying a second identical sweep.
        let final_val = match last_val {
            Some((t, v)) if t + 1 == steps => v,
            _ => self.validate(self.cfg.eval_batches)?,
        };
        Ok(TrainOutcome {
            final_train_loss: last_train,
            final_val_loss: final_val,
            tokens: self.tokens_seen,
            steps,
            wall_secs: self.wall.elapsed_secs(),
        })
    }

    pub fn checkpoint_path(&self, step: u64) -> PathBuf {
        self.cfg
            .out_dir
            .join(&self.cfg.run_name)
            .join(format!("step_{step}.ckpt"))
    }

    pub fn save_checkpoint(&self, step: u64) -> Result<PathBuf> {
        let path = self.checkpoint_path(step);
        Checkpoint {
            step,
            tokens_seen: Some(self.tokens_seen),
            names: self
                .manifest
                .params
                .iter()
                .map(|p| p.name.clone())
                .collect(),
            params: self.supervisor.engine().params().to_vec(),
            opt_state: self.supervisor.engine().export_state(),
        }
        .save(&path)?;
        Ok(path)
    }

    /// Resume parameters + optimizer state from a checkpoint. Parameters
    /// are re-installed through the engine (sharded engines re-scatter
    /// into their workers) and optimizer state flows through
    /// [`TrainEngine::import_state`].
    ///
    /// **Elastic**: a v3+ checkpoint stores the canonical (world-agnostic)
    /// optimizer form, so the source run's `--parallel` mode and world
    /// size don't have to match this trainer's — FSDP moments are
    /// re-sliced for the new world (`checkpoint::canonical`). State that
    /// cannot be re-sliced exactly at this mode/world (misaligned
    /// block-quantized adam8bit moments, adafactor's factored
    /// cross-statistics) imports only behind the explicit
    /// `--resume-requantize` / `[train] resume_requantize` opt-in — loud,
    /// never silent. Legacy v2 checkpoints remain world-locked under FSDP
    /// and fail loudly on a mismatch. Note that changing the world also
    /// changes how microbatch data is dealt across ranks, so only a
    /// same-world resume reproduces the uninterrupted *loss* trajectory;
    /// optimizer state itself is restored exactly either way (pinned in
    /// tests/resharding.rs).
    pub fn resume(&mut self, path: &Path) -> Result<u64> {
        let ckpt = Checkpoint::load(path)?;
        anyhow::ensure!(
            ckpt.params.len() == self.supervisor.engine().params().len(),
            "checkpoint param count mismatch"
        );
        self.supervisor.engine_mut().init_params(&ckpt.params);
        let opts = crate::train::ImportOpts {
            requantize: self.cfg.resume_requantize,
        };
        self.supervisor
            .engine_mut()
            .import_state_with(&ckpt.opt_state, opts)
            .map_err(|e| anyhow::anyhow!("optimizer state: {e}"))?;
        self.start_step = ckpt.step;
        // Telemetry continuity: v4 checkpoints record the exact counter,
        // so even an ELASTIC resume (different world, hence different
        // tokens-per-step) reports the true token axis. Pre-v4 files
        // don't carry it; reconstruct from THIS run's consumption rate —
        // exact for a same-world resume, a documented rescaling otherwise.
        let world = self.supervisor.engine().world() as u64;
        self.tokens_seen = ckpt
            .tokens_seen
            .unwrap_or_else(|| ckpt.step * world * self.loader.tokens_per_batch() as u64);
        Ok(ckpt.step)
    }

    /// Per-rank memory/traffic reports (FSDP and DDP engines).
    pub fn memory_reports(&self) -> Option<Vec<MemoryReport>> {
        self.supervisor.engine().memory_reports()
    }

    pub fn runtime(&self) -> Arc<Runtime> {
        self.rt.clone()
    }
}
