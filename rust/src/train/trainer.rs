//! The trainer: config → artifacts → data → step loop → metrics.
//!
//! Per step (single-process):
//!   1. draw a packed batch,
//!   2. execute the fwd_bwd artifact (loss + per-param grads),
//!   3. run the optimizer (native GaLore / PJRT-kernel GaLore / baselines),
//!   4. log; periodically sweep validation and checkpoint.
//!
//! Under FSDP/DDP the gradients of each rank's microbatch are computed via
//! the same artifact, then handed to the distributed engine whose worker
//! threads own shards + optimizer state (rust/src/dist/).
//!
//! Parallel execution: `cfg.threads` sets the process-wide worker-pool
//! default (`crate::parallel`), so the per-layer optimizer stepping below
//! fans its projection/reprojection GEMMs and SVD refreshes across cores;
//! under FSDP the per-layer loop itself additionally runs concurrently
//! across the cluster's worker threads. Both layers of parallelism are
//! bitwise deterministic (fixed-tree reductions, panel-local kernels).

use crate::checkpoint::Checkpoint;
use crate::config::{Engine, ParallelMode, TrainConfig};
use crate::data::{Batch, Corpus, CorpusCfg, DataLoader};
use crate::dist::FsdpCluster;
use crate::dist::ParamMeta;
use crate::metrics::Metrics;
use crate::model::LlamaCfg;
use crate::optim::lr::Schedule;
use crate::optim::Optimizer;
use crate::runtime::{Executable, HostTensor, Manifest, Runtime};
use crate::tensor::Matrix;
use crate::train::PjrtGaLore;
use crate::util::Timer;
use anyhow::{bail, Context, Result};
use std::sync::Arc;

enum Mode {
    Single {
        opt: Box<dyn Optimizer>,
    },
    Fsdp {
        cluster: FsdpCluster,
    },
}

pub struct Trainer {
    pub cfg: TrainConfig,
    pub llama: LlamaCfg,
    pub manifest: Manifest,
    rt: Arc<Runtime>,
    fwd_bwd: Arc<Executable>,
    pub loader: DataLoader,
    pub schedule: Schedule,
    pub metrics: Metrics,
    /// Full parameters as seen by the compute device.
    pub params: Vec<Matrix>,
    mode: Mode,
    pub tokens_seen: u64,
    start_step: u64,
    wall: Timer,
}

/// Summary of a finished run.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub final_train_loss: f64,
    pub final_val_loss: f64,
    pub tokens: u64,
    pub steps: u64,
    pub wall_secs: f64,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Result<Trainer> {
        // Pin the compute pool before any kernel runs; 0 keeps auto-detect.
        crate::parallel::set_default_threads(cfg.threads);
        let llama = LlamaCfg::preset(&cfg.preset)
            .with_context(|| format!("unknown preset {:?}", cfg.preset))?;
        let manifest = Manifest::load(
            cfg.artifacts_dir
                .join(format!("manifest_{}.json", cfg.preset)),
        )
        .with_context(|| {
            format!(
                "manifest for {} missing — run `make artifacts PRESET={}`",
                cfg.preset, cfg.preset
            )
        })?;
        let rt = Arc::new(Runtime::cpu()?);
        let fwd_bwd = rt.load(
            cfg.artifacts_dir
                .join(&manifest.artifacts["fwd_bwd"]),
        )?;

        let corpus = Corpus::new(CorpusCfg {
            vocab: llama.vocab,
            branching: 8,
            order: 1,
            seed: cfg.seed ^ 0xc0de,
        });
        let loader = DataLoader::new(
            &corpus,
            cfg.corpus_tokens,
            cfg.val_tokens,
            llama.batch,
            llama.seq,
            cfg.seed,
        );

        let params = crate::model::init_params(&llama, cfg.seed);
        let schedule = Schedule::WarmupCosine {
            peak: cfg.lr,
            warmup: ((cfg.steps as f64 * cfg.warmup_frac) as u64).max(1),
            total: cfg.steps,
            floor_frac: cfg.lr_floor_frac,
        };

        let mode = match cfg.parallel {
            ParallelMode::Single => {
                let opt: Box<dyn Optimizer> = match (cfg.engine, cfg.optimizer.as_str()) {
                    (Engine::Pjrt, "galore") => Box::new(PjrtGaLore::new(
                        cfg.galore_cfg(llama.hidden)?,
                        cfg.adam_cfg(),
                        rt.clone(),
                        cfg.artifacts_dir.clone(),
                        manifest.clone(),
                        cfg.seed,
                    )),
                    (Engine::Pjrt, other) => {
                        bail!("engine=pjrt only applies to galore (got {other})")
                    }
                    (Engine::Native, "galore") => Box::new(crate::optim::GaLore::new(
                        cfg.galore_cfg(llama.hidden)?,
                        cfg.adam_cfg(),
                        cfg.seed,
                    )),
                    (Engine::Native, "qgalore") => {
                        let mut g = cfg.galore_cfg(llama.hidden)?;
                        g.projection = crate::optim::ProjectionKind::Quant8;
                        Box::new(crate::optim::QGaLore::new(
                            crate::optim::QGaLoreCfg {
                                galore: g,
                                similarity_threshold: 0.9,
                            },
                            cfg.adam_cfg(),
                            cfg.seed,
                        ))
                    }
                    (Engine::Native, "adamw") => {
                        Box::new(crate::optim::AdamW::new(cfg.adam_cfg()))
                    }
                    (Engine::Native, "adam8bit") => {
                        Box::new(crate::optim::Adam8bit::new(cfg.adam_cfg()))
                    }
                    (Engine::Native, "adafactor") => {
                        Box::new(crate::optim::Adafactor::new(1e-30))
                    }
                    (Engine::Native, "sgdm") => Box::new(crate::optim::SgdM::new(0.9)),
                    (Engine::Native, other) => bail!("unknown optimizer {other:?}"),
                };
                Mode::Single { opt }
            }
            ParallelMode::Fsdp => {
                let metas: Vec<ParamMeta> = manifest
                    .params
                    .iter()
                    .map(|p| {
                        let (rows, cols) = p.matrix_shape();
                        ParamMeta {
                            name: p.name.clone(),
                            rows,
                            cols,
                        }
                    })
                    .collect();
                let cluster = FsdpCluster::new(
                    cfg.world.max(1),
                    metas,
                    cfg.optimizer_spec(llama.hidden)?,
                    cfg.seed,
                );
                cluster.init_params(&params);
                Mode::Fsdp { cluster }
            }
            ParallelMode::Ddp => bail!(
                "ddp mode is exposed through dist::run_ddp (see \
                 benches/table1_fsdp_memory.rs); the trainer uses single or fsdp"
            ),
        };

        Ok(Trainer {
            cfg,
            llama,
            manifest,
            rt,
            fwd_bwd,
            loader,
            schedule,
            metrics: Metrics::new(),
            params,
            mode,
            tokens_seen: 0,
            start_step: 0,
            wall: Timer::start(),
        })
    }

    /// Inputs for one execution: params (in ABI shapes) + tokens + targets.
    fn build_inputs(&self, batch: &Batch) -> Vec<HostTensor> {
        let mut inputs: Vec<HostTensor> = self
            .manifest
            .params
            .iter()
            .zip(&self.params)
            .map(|(spec, m)| {
                if spec.shape.len() == 1 {
                    HostTensor::from_vec1(&m.data)
                } else {
                    HostTensor::from_matrix(m)
                }
            })
            .collect();
        inputs.push(HostTensor::tokens(&batch.tokens, batch.batch, batch.seq));
        inputs.push(HostTensor::tokens(&batch.targets, batch.batch, batch.seq));
        inputs
    }

    /// Execute fwd_bwd on a batch: (loss, grads as matrices).
    fn compute_grads(&self, batch: &Batch) -> Result<(f32, Vec<Matrix>)> {
        let out = self.fwd_bwd.run(&self.build_inputs(batch))?;
        let loss = out[0][0];
        let grads = self
            .manifest
            .params
            .iter()
            .zip(out.into_iter().skip(1))
            .map(|(spec, data)| {
                let (r, c) = spec.matrix_shape();
                Matrix::from_vec(r, c, data)
            })
            .collect();
        Ok((loss, grads))
    }

    /// One optimizer step; returns the training loss of this step's batch.
    pub fn train_step(&mut self, t: u64) -> Result<f32> {
        let lr = self.schedule.lr(t);
        let loss = match self.cfg.parallel {
            ParallelMode::Single => {
                let batch = self.loader.train_batch_at(t, 0);
                self.tokens_seen += (batch.batch * batch.seq) as u64;
                let (loss, grads) = self.compute_grads(&batch)?;
                let Mode::Single { opt } = &mut self.mode else {
                    unreachable!()
                };
                opt.begin_step(t);
                for (idx, grad) in grads.into_iter().enumerate() {
                    opt.step_param(idx, &mut self.params[idx], &grad, lr);
                    // grad dropped here — per-layer update semantics.
                }
                loss
            }
            _ => {
                // Each rank computes gradients on its own microbatch.
                let world = self.cfg.world.max(1);
                let batches = self.loader.train_microbatches_at(t, world);
                self.tokens_seen +=
                    (world * self.loader.tokens_per_batch()) as u64;
                let mut losses = Vec::with_capacity(world);
                let mut per_rank = Vec::with_capacity(world);
                for b in &batches {
                    let (l, g) = self.compute_grads(b)?;
                    losses.push(l);
                    per_rank.push(g);
                }
                let Mode::Fsdp { cluster } = &mut self.mode else {
                    unreachable!()
                };
                cluster.step(t, per_rank, lr);
                self.params = cluster.gather_params();
                losses.iter().sum::<f32>() / world as f32
            }
        };
        Ok(loss)
    }

    /// Mean validation loss over `batches` deterministic windows.
    pub fn validate(&mut self, batches: usize) -> Result<f64> {
        self.loader.reset_val();
        let mut total = 0f64;
        for _ in 0..batches.max(1) {
            let batch = self.loader.next_val();
            let (loss, _) = self.compute_grads(&batch)?;
            total += loss as f64;
        }
        Ok(total / batches.max(1) as f64)
    }

    /// Full training run with logging / eval / checkpoints.
    pub fn run(&mut self) -> Result<TrainOutcome> {
        let steps = self.cfg.steps;
        let mut last_train = f64::NAN;
        for t in self.start_step..steps {
            let loss = self.train_step(t)? as f64;
            last_train = loss;
            if t % self.cfg.log_every == 0 || t + 1 == steps {
                self.metrics.log(
                    "train",
                    t,
                    self.tokens_seen,
                    loss,
                    self.schedule.lr(t) as f64,
                    self.wall.elapsed_secs(),
                );
            }
            if self.cfg.eval_every > 0
                && (t % self.cfg.eval_every == 0 || t + 1 == steps)
            {
                let val = self.validate(self.cfg.eval_batches)?;
                self.metrics.log(
                    "val",
                    t,
                    self.tokens_seen,
                    val,
                    self.schedule.lr(t) as f64,
                    self.wall.elapsed_secs(),
                );
            }
            if self.cfg.checkpoint_every > 0
                && t > 0
                && t % self.cfg.checkpoint_every == 0
            {
                self.save_checkpoint(t)?;
            }
        }
        let final_val = self.validate(self.cfg.eval_batches)?;
        Ok(TrainOutcome {
            final_train_loss: last_train,
            final_val_loss: final_val,
            tokens: self.tokens_seen,
            steps,
            wall_secs: self.wall.elapsed_secs(),
        })
    }

    pub fn checkpoint_path(&self, step: u64) -> std::path::PathBuf {
        self.cfg
            .out_dir
            .join(&self.cfg.run_name)
            .join(format!("step_{step}.ckpt"))
    }

    pub fn save_checkpoint(&self, step: u64) -> Result<()> {
        let opt_state = match &self.mode {
            Mode::Single { opt } => opt.export_state(),
            Mode::Fsdp { cluster } => cluster.export_rank0_optimizer(),
        };
        Checkpoint {
            step,
            names: self
                .manifest
                .params
                .iter()
                .map(|p| p.name.clone())
                .collect(),
            params: self.params.clone(),
            opt_state,
        }
        .save(self.checkpoint_path(step))?;
        Ok(())
    }

    /// Resume parameters + optimizer state from a checkpoint (single mode).
    pub fn resume(&mut self, path: &std::path::Path) -> Result<u64> {
        let ckpt = Checkpoint::load(path)?;
        anyhow::ensure!(
            ckpt.params.len() == self.params.len(),
            "checkpoint param count mismatch"
        );
        self.params = ckpt.params;
        if let Mode::Single { opt } = &mut self.mode {
            opt.import_state(&ckpt.opt_state)
                .map_err(|e| anyhow::anyhow!("optimizer state: {e}"))?;
        }
        self.start_step = ckpt.step;
        Ok(ckpt.step)
    }

    /// Per-GPU memory reports when running FSDP.
    pub fn fsdp_memory(&self) -> Option<Vec<crate::dist::MemoryReport>> {
        match &self.mode {
            Mode::Fsdp { cluster } => Some(cluster.memory_reports()),
            _ => None,
        }
    }

    pub fn runtime(&self) -> Arc<Runtime> {
        self.rt.clone()
    }
}
