//! Training loop: the coordinator's per-step orchestration.

mod pjrt_galore;
mod trainer;

pub use pjrt_galore::PjrtGaLore;
pub use trainer::{TrainOutcome, Trainer};
