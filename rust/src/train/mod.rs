//! Training loop: the coordinator's per-step orchestration.
//!
//! * [`Trainer`] — config → artifacts → data → step loop.
//! * [`TrainEngine`] — the execution-mode abstraction ([`SingleEngine`],
//!   [`FsdpEngine`], [`DdpEngine`]); one trait per mode, one optimizer
//!   construction path (`OptimizerSpec::build`) behind all of them.
//! * [`StepObserver`] / [`StepEvent`] — the trainer's event stream.
//! * [`Supervisor`] — fault tolerance: rolling in-memory snapshots, and
//!   worker deaths converted into rebuild-at-world → re-shard → replay
//!   cycles per [`OnFailure`] (`--on-failure abort|respawn|shrink`).

mod engine;
mod observer;
mod pjrt_galore;
mod supervisor;
mod trainer;

pub use crate::checkpoint::canonical::ImportOpts;
pub use engine::{DdpEngine, FsdpEngine, SingleEngine, TrainEngine};
pub use observer::{StepEvent, StepObserver};
pub use pjrt_galore::PjrtGaLore;
pub use supervisor::{
    EngineFactory, OnFailure, RecoveryPolicy, Snapshot, Supervised, Supervisor,
};
pub use trainer::{TrainOutcome, Trainer};
