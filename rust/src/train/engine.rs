//! [`TrainEngine`] — one trait per execution mode.
//!
//! The trainer's step loop is mode-agnostic: it computes per-rank
//! microbatch gradients through the fwd_bwd artifact and hands them to an
//! engine, which owns the parameters (full or sharded) and the optimizer
//! state, however it is distributed. Adding an execution mode means
//! implementing this trait — the optimizer construction matrix stays
//! untouched because every engine builds through [`OptimizerSpec::build`].
//! Orthogonally, the distributed engines take a
//! [`TransportKind`] (`--transport threads|process`) choosing whether
//! their ranks are worker threads or Unix-socket worker processes; the
//! trajectory is bitwise identical either way.
//!
//! Engines:
//! * [`SingleEngine`] — in-process optimizer (native or PJRT-kernel).
//! * [`FsdpEngine`]   — sharded state over [`FsdpCluster`] worker threads.
//! * [`DdpEngine`]    — replicated state over [`DdpCluster`] worker
//!   threads; world=1 trajectories are bitwise equal to [`SingleEngine`].
//!
//! Checkpoint state flows through `export_state`/`import_state` in the
//! **canonical, world-agnostic form** ([`CanonicalOptState`]): every
//! engine exports the same bytes for the same trajectory, and every
//! engine imports state exported by any other engine at any world size —
//! the elastic-resume contract (`tests/resharding.rs`). Legacy (v2)
//! mode-specific blobs are still accepted on import, but remain
//! world-locked for FSDP and fail loudly on mismatch.

use crate::checkpoint::canonical::{CanonicalOptState, ImportOpts};
use crate::dist::{
    DdpCluster, FsdpCluster, MemoryReport, ParamMeta, StepTiming, StepTraffic, TransportKind,
    WorkerLoss,
};
use crate::optim::spec::{BuildTarget, OptimizerSpec, PjrtResources, WorkerOpt};
use crate::tensor::Matrix;

/// An execution mode: owns parameters + optimizer state, applies steps.
pub trait TrainEngine {
    /// Execution-mode name ("single" | "fsdp" | "ddp").
    fn name(&self) -> &'static str;

    /// Name of the optimizer the spec built ("galore", "qgalore", …).
    fn optimizer_name(&self) -> &'static str;

    /// Number of per-rank gradient sets `step` expects.
    fn world(&self) -> usize;

    /// (Re)install full parameters — initialization and checkpoint resume
    /// (sharded engines re-scatter into their workers here).
    fn init_params(&mut self, full: &[Matrix]);

    /// Current full (unsharded) parameters.
    fn params(&self) -> &[Matrix];

    /// One synchronous optimizer step. `per_rank_grads[r]` holds rank r's
    /// microbatch gradients in full shapes; `lr` is the scheduled rate.
    /// Panics on worker death (the PR 4 prompt-failure contract);
    /// [`TrainEngine::try_step`] is the caught form.
    fn step(&mut self, t: u64, per_rank_grads: Vec<Vec<Matrix>>, lr: f32) {
        self.try_step(t, per_rank_grads, lr)
            .unwrap_or_else(|loss| panic!("{loss}"));
    }

    /// [`TrainEngine::step`], but a worker rank dying mid-step comes back
    /// as `Err(WorkerLoss)` naming the rank that failed first — the hook
    /// the recovery supervisor (`train/supervisor.rs`) catches. Single-
    /// process engines never fail this way.
    fn try_step(
        &mut self,
        t: u64,
        per_rank_grads: Vec<Vec<Matrix>>,
        lr: f32,
    ) -> Result<(), WorkerLoss>;

    /// Serialized optimizer state in the canonical (world-agnostic) form:
    /// round-trips through `import_state` on an engine of ANY mode and
    /// world size (for re-shardable optimizers; world-locked state says so
    /// on import instead of silently resetting).
    fn export_state(&self) -> Vec<u8>;

    /// Exact-only import: every restore is bitwise or a loud error.
    /// Equivalent to [`TrainEngine::import_state_with`] under the default
    /// [`ImportOpts`].
    fn import_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        self.import_state_with(bytes, ImportOpts::default())
    }

    /// Import with an explicit policy: `opts.requantize` opts into the
    /// lossy conversions (`--resume-requantize`) for state that cannot be
    /// re-sliced exactly at this engine's mode/world — re-blocking
    /// quantized adam8bit moments, merging/replicating adafactor's
    /// factored cross-statistics.
    fn import_state_with(&mut self, bytes: &[u8], opts: ImportOpts) -> Result<(), String>;

    /// Per-rank memory/traffic telemetry (None for single-process).
    fn memory_reports(&self) -> Option<Vec<MemoryReport>>;

    /// Comm/compute timing of the most recent successful step — the
    /// slowest rank's worker-blocked collective time vs the rest of its
    /// step wall (None for single-process engines, which do no
    /// communication). Feeds `StepEvent::StepTimed`; observability only.
    fn last_step_timing(&self) -> Option<StepTiming> {
        None
    }

    /// Data-plane traffic of the most recent successful step — payload
    /// bytes summed across ranks plus the largest rank's transient
    /// footprint (None for single-process engines). Feeds
    /// `StepEvent::StepTraffic`; observability only.
    fn last_step_traffic(&self) -> Option<StepTraffic> {
        None
    }
}

/// Synthesize parameter metas from full parameter matrices — the geometry
/// the canonical import conversions need when an engine (SingleEngine)
/// holds no explicit meta table.
fn metas_from_params(params: &[Matrix]) -> Vec<ParamMeta> {
    params
        .iter()
        .enumerate()
        .map(|(i, p)| ParamMeta {
            name: format!("param{i}"),
            rows: p.rows,
            cols: p.cols,
        })
        .collect()
}

/// Single-process engine: one optimizer instance stepping in place.
pub struct SingleEngine {
    opt: WorkerOpt,
    /// Layout of `opt`'s state blob — can differ from its display name
    /// (a quantized-projector GaLore reports "qgalore" but serializes the
    /// raw layout); the canonical boundary converts on it.
    codec: &'static str,
    params: Vec<Matrix>,
}

impl SingleEngine {
    pub fn new(
        spec: &OptimizerSpec,
        seed: u64,
        pjrt: Option<&PjrtResources>,
        params: Vec<Matrix>,
    ) -> Result<SingleEngine, String> {
        Ok(SingleEngine {
            opt: spec.build(seed, BuildTarget::Single { pjrt })?,
            codec: spec.state_codec(false),
            params,
        })
    }
}

impl TrainEngine for SingleEngine {
    fn name(&self) -> &'static str {
        "single"
    }

    fn optimizer_name(&self) -> &'static str {
        self.opt.name()
    }

    fn world(&self) -> usize {
        1
    }

    fn init_params(&mut self, full: &[Matrix]) {
        self.params = full.to_vec();
    }

    fn params(&self) -> &[Matrix] {
        &self.params
    }

    fn try_step(
        &mut self,
        t: u64,
        per_rank_grads: Vec<Vec<Matrix>>,
        lr: f32,
    ) -> Result<(), WorkerLoss> {
        assert_eq!(per_rank_grads.len(), 1, "single engine takes one rank");
        let grads = per_rank_grads.into_iter().next().unwrap();
        assert_eq!(grads.len(), self.params.len(), "grad/param count");
        let opt = self.opt.as_opt();
        opt.begin_step(t);
        for (idx, grad) in grads.into_iter().enumerate() {
            opt.step_param(idx, &mut self.params[idx], &grad, lr);
            // grad dropped here — per-layer update semantics.
        }
        Ok(())
    }

    fn export_state(&self) -> Vec<u8> {
        CanonicalOptState::from_full(self.opt.name(), self.codec, self.opt.export_state())
            .expect("canonicalizing optimizer state")
            .encode()
    }

    fn import_state_with(&mut self, bytes: &[u8], opts: ImportOpts) -> Result<(), String> {
        if CanonicalOptState::sniff(bytes) {
            let c = CanonicalOptState::decode(bytes)?;
            c.expect_name(self.opt.name())?;
            let metas = metas_from_params(&self.params);
            self.opt
                .as_opt()
                .import_state(&c.to_full_for(self.codec, &metas, opts)?)
        } else {
            // Legacy (v2) checkpoint: the raw single-process blob.
            self.opt.as_opt().import_state(bytes)
        }
    }

    fn memory_reports(&self) -> Option<Vec<MemoryReport>> {
        None
    }
}

/// FSDP engine: sharded parameters + optimizer state across worker
/// threads; keeps a gathered full-parameter view for the fwd_bwd artifact.
pub struct FsdpEngine {
    cluster: FsdpCluster,
    params: Vec<Matrix>,
}

impl FsdpEngine {
    pub fn new(
        world: usize,
        metas: Vec<ParamMeta>,
        spec: OptimizerSpec,
        seed: u64,
        init: &[Matrix],
    ) -> Result<FsdpEngine, String> {
        Self::with_transport(world, metas, spec, seed, init, TransportKind::Threads)
    }

    /// [`FsdpEngine::new`] with an explicit worker transport
    /// (`--transport threads|process`). The trajectory is bitwise
    /// identical either way (`tests/transport.rs`).
    pub fn with_transport(
        world: usize,
        metas: Vec<ParamMeta>,
        spec: OptimizerSpec,
        seed: u64,
        init: &[Matrix],
        transport: TransportKind,
    ) -> Result<FsdpEngine, String> {
        if !spec.distributed_ok() {
            return Err(format!("{} cannot run under fsdp", spec.name()));
        }
        let cluster = FsdpCluster::with_transport(world, metas, spec, seed, transport)?;
        cluster.init_params(init);
        Ok(FsdpEngine {
            cluster,
            params: init.to_vec(),
        })
    }
}

impl TrainEngine for FsdpEngine {
    fn name(&self) -> &'static str {
        "fsdp"
    }

    fn optimizer_name(&self) -> &'static str {
        self.cluster.optimizer_name()
    }

    fn world(&self) -> usize {
        self.cluster.world()
    }

    fn init_params(&mut self, full: &[Matrix]) {
        self.cluster.init_params(full);
        self.params = full.to_vec();
    }

    fn params(&self) -> &[Matrix] {
        &self.params
    }

    fn try_step(
        &mut self,
        t: u64,
        per_rank_grads: Vec<Vec<Matrix>>,
        lr: f32,
    ) -> Result<(), WorkerLoss> {
        self.cluster.try_step(t, per_rank_grads, lr)?;
        self.params = self.cluster.try_gather_params()?;
        Ok(())
    }

    fn export_state(&self) -> Vec<u8> {
        // Gather every rank's shard-local frame into the world-agnostic
        // canonical form. A parse failure here means a worker serialized
        // corrupt state — an internal invariant, not a user error.
        let frames = self.cluster.export_frames();
        CanonicalOptState::from_fsdp_frames(
            self.cluster.optimizer_name(),
            frames,
            self.cluster.metas(),
        )
        .expect("canonicalizing FSDP optimizer state")
        .encode()
    }

    fn import_state_with(&mut self, bytes: &[u8], opts: ImportOpts) -> Result<(), String> {
        if CanonicalOptState::sniff(bytes) {
            let c = CanonicalOptState::decode(bytes)?;
            c.expect_name(self.cluster.optimizer_name())?;
            let frames = c.fsdp_frames(self.cluster.world(), self.cluster.metas(), opts)?;
            self.cluster.import_frames(frames)
        } else {
            // Legacy (v2) checkpoint: world-locked per-rank frames; the
            // cluster rejects world mismatches with a migration hint.
            self.cluster.import_optimizers(bytes)
        }
    }

    fn memory_reports(&self) -> Option<Vec<MemoryReport>> {
        Some(self.cluster.memory_reports())
    }

    fn last_step_timing(&self) -> Option<StepTiming> {
        self.cluster.last_step_timing()
    }

    fn last_step_traffic(&self) -> Option<StepTraffic> {
        self.cluster.last_step_traffic()
    }
}

/// DDP engine: replicated parameters + optimizer state; every gather
/// verifies the replicas are still bitwise identical.
pub struct DdpEngine {
    cluster: DdpCluster,
    /// Layout of the workers' state blobs (see [`OptimizerSpec::state_codec`]).
    codec: &'static str,
    params: Vec<Matrix>,
}

impl DdpEngine {
    pub fn new(
        world: usize,
        metas: Vec<ParamMeta>,
        spec: OptimizerSpec,
        seed: u64,
        init: &[Matrix],
    ) -> Result<DdpEngine, String> {
        Self::with_transport(world, metas, spec, seed, init, TransportKind::Threads)
    }

    /// [`DdpEngine::new`] with an explicit worker transport
    /// (`--transport threads|process`). The trajectory is bitwise
    /// identical either way (`tests/transport.rs`).
    pub fn with_transport(
        world: usize,
        metas: Vec<ParamMeta>,
        spec: OptimizerSpec,
        seed: u64,
        init: &[Matrix],
        transport: TransportKind,
    ) -> Result<DdpEngine, String> {
        if !spec.distributed_ok() {
            return Err(format!("{} cannot run under ddp", spec.name()));
        }
        let codec = spec.state_codec(false);
        let cluster = DdpCluster::with_transport(world, metas, spec, seed, transport)?;
        cluster.init_params(init);
        Ok(DdpEngine {
            cluster,
            codec,
            params: init.to_vec(),
        })
    }
}

impl TrainEngine for DdpEngine {
    fn name(&self) -> &'static str {
        "ddp"
    }

    fn optimizer_name(&self) -> &'static str {
        self.cluster.optimizer_name()
    }

    fn world(&self) -> usize {
        self.cluster.world()
    }

    fn init_params(&mut self, full: &[Matrix]) {
        self.cluster.init_params(full);
        self.params = full.to_vec();
    }

    fn params(&self) -> &[Matrix] {
        &self.params
    }

    fn try_step(
        &mut self,
        t: u64,
        per_rank_grads: Vec<Vec<Matrix>>,
        lr: f32,
    ) -> Result<(), WorkerLoss> {
        self.cluster.try_step(t, per_rank_grads, lr)?;
        // Cheap per-step view: replicas are identical by construction, so
        // one rank's copy suffices (full equality is asserted at
        // checkpoint time and by DdpCluster::gather_params users).
        self.params = self.cluster.try_rank0_params()?;
        Ok(())
    }

    fn export_state(&self) -> Vec<u8> {
        // Checkpoint gate: panic here, not after persisting, if the
        // replicas have somehow diverged. Replicated state is already
        // full-tensor — rank 0's blob is the canonical payload (converted
        // to the canonical layout where the display name requires it).
        let _ = self.cluster.gather_params();
        CanonicalOptState::from_full(
            self.cluster.optimizer_name(),
            self.codec,
            self.cluster.export_optimizer(),
        )
        .expect("canonicalizing optimizer state")
        .encode()
    }

    fn import_state_with(&mut self, bytes: &[u8], opts: ImportOpts) -> Result<(), String> {
        if CanonicalOptState::sniff(bytes) {
            let c = CanonicalOptState::decode(bytes)?;
            c.expect_name(self.cluster.optimizer_name())?;
            self.cluster.import_optimizer(&c.to_full_for(
                self.codec,
                self.cluster.metas(),
                opts,
            )?)
        } else {
            // Legacy (v2) checkpoint: the raw replicated blob.
            self.cluster.import_optimizer(bytes)
        }
    }

    fn memory_reports(&self) -> Option<Vec<MemoryReport>> {
        Some(self.cluster.memory_reports())
    }

    fn last_step_timing(&self) -> Option<StepTiming> {
        self.cluster.last_step_timing()
    }

    fn last_step_traffic(&self) -> Option<StepTraffic> {
        self.cluster.last_step_traffic()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::AdamCfg;
    use crate::util::rng::Pcg64;

    fn setup(shapes: &[(usize, usize)]) -> (Vec<ParamMeta>, Vec<Matrix>, Vec<Matrix>) {
        let mut rng = Pcg64::new(11, 0);
        let metas = shapes
            .iter()
            .enumerate()
            .map(|(i, &(r, c))| ParamMeta {
                name: format!("p{i}"),
                rows: r,
                cols: c,
            })
            .collect();
        let init: Vec<Matrix> = shapes
            .iter()
            .map(|&(r, c)| Matrix::randn(r, c, 0.5, &mut rng))
            .collect();
        let grads: Vec<Matrix> = shapes
            .iter()
            .map(|&(r, c)| Matrix::randn(r, c, 0.1, &mut rng))
            .collect();
        (metas, init, grads)
    }

    #[test]
    fn all_engines_agree_at_world_one() {
        // The trait-level statement of the §4.3 claim: one recipe, any
        // execution mode — world-1 trajectories are identical.
        let shapes = &[(8, 12), (12, 8), (1, 8)];
        let (metas, init, grads) = setup(shapes);
        let spec = OptimizerSpec::AdamW(AdamCfg::default());
        let mut engines: Vec<Box<dyn TrainEngine>> = vec![
            Box::new(SingleEngine::new(&spec, 5, None, init.clone()).unwrap()),
            Box::new(FsdpEngine::new(1, metas.clone(), spec.clone(), 5, &init).unwrap()),
            Box::new(DdpEngine::new(1, metas, spec.clone(), 5, &init).unwrap()),
        ];
        for t in 0..5 {
            for e in engines.iter_mut() {
                e.step(t, vec![grads.clone()], 0.05);
            }
        }
        let names: Vec<&str> = engines.iter().map(|e| e.name()).collect();
        assert_eq!(names, ["single", "fsdp", "ddp"]);
        for e in &engines {
            assert_eq!(e.optimizer_name(), "adamw");
            assert_eq!(e.world(), 1);
        }
        let base = engines[0].params().to_vec();
        for e in &engines[1..] {
            for (idx, (a, b)) in base.iter().zip(e.params()).enumerate() {
                assert_eq!(
                    a.data,
                    b.data,
                    "param {idx}: {} diverged from single",
                    e.name()
                );
            }
        }
    }

    #[test]
    fn engine_state_roundtrips_via_trait_surface() {
        // export_state → fresh engine → init_params + import_state must
        // resume the exact trajectory, for every engine mode.
        let shapes = &[(6, 10), (10, 6)];
        let (metas, init, grads) = setup(shapes);
        let spec = OptimizerSpec::AdamW(AdamCfg::default());
        let builders: Vec<Box<dyn Fn() -> Box<dyn TrainEngine>>> = vec![
            Box::new({
                let (spec, init) = (spec.clone(), init.clone());
                move || {
                    Box::new(SingleEngine::new(&spec, 3, None, init.clone()).unwrap())
                        as Box<dyn TrainEngine>
                }
            }),
            Box::new({
                let (spec, metas, init) = (spec.clone(), metas.clone(), init.clone());
                move || {
                    Box::new(
                        FsdpEngine::new(2, metas.clone(), spec.clone(), 3, &init).unwrap(),
                    ) as Box<dyn TrainEngine>
                }
            }),
            Box::new({
                let (spec, metas, init) = (spec.clone(), metas.clone(), init.clone());
                move || {
                    Box::new(DdpEngine::new(2, metas.clone(), spec.clone(), 3, &init).unwrap())
                        as Box<dyn TrainEngine>
                }
            }),
        ];
        for make in builders {
            let mut a = make();
            let world = a.world();
            a.step(0, vec![grads.clone(); world], 0.05);
            let blob = a.export_state();
            let snapshot = a.params().to_vec();
            let mut b = make();
            b.init_params(&snapshot);
            b.import_state(&blob).unwrap();
            a.step(1, vec![grads.clone(); world], 0.05);
            b.step(1, vec![grads.clone(); world], 0.05);
            for (idx, (x, y)) in a.params().iter().zip(b.params()).enumerate() {
                assert_eq!(
                    x.data,
                    y.data,
                    "param {idx}: {} resume diverged",
                    a.name()
                );
            }
        }
    }

    #[test]
    fn import_rejects_mismatched_optimizer_state() {
        // A galore checkpoint must never silently feed adamw moments.
        let shapes = &[(6, 10)];
        let (_, init, _) = setup(shapes);
        let adamw = SingleEngine::new(
            &OptimizerSpec::AdamW(AdamCfg::default()),
            3,
            None,
            init.clone(),
        )
        .unwrap();
        let blob = adamw.export_state();
        let mut galore = SingleEngine::new(
            &OptimizerSpec::GaLore {
                galore: crate::optim::GaLoreCfg::default(),
                adam: AdamCfg::default(),
            },
            3,
            None,
            init,
        )
        .unwrap();
        let err = galore.import_state(&blob).unwrap_err();
        assert!(
            err.contains("adamw") && err.contains("galore"),
            "unhelpful error: {err}"
        );
    }
}
