//! Fault-tolerant training supervision: snapshot → re-shard → continue.
//!
//! GaLore 2's headline pre-training horizon (Llama 7B, 500B tokens under
//! FSDP) makes worker failure a certainty, not an edge case. PRs 3–5
//! built the two halves a recovery path needs — world-agnostic canonical
//! optimizer state (`checkpoint::canonical`) and transport-abstracted
//! clusters whose worker deaths surface as prompt, attributable
//! coordinator errors. The [`Supervisor`] composes them:
//!
//! 1. **Snapshot** — a rolling in-memory [`Snapshot`] (full params +
//!    canonical optimizer bytes + the exact `tokens_seen` counter) is
//!    captured every `snapshot_every` steps ([`Supervisor::maybe_snapshot`],
//!    `[train] snapshot_every` / `--snapshot-every`). Nothing touches
//!    disk; the checkpoint cadence stays independent.
//! 2. **Catch** — [`Supervisor::step`] drives
//!    [`TrainEngine::try_step`]; a [`WorkerLoss`] (thread panic, child
//!    exit, socket drop — either transport) becomes a recovery event, not
//!    a crash.
//! 3. **Rebuild** — the dead cluster is dropped (its Drop reaps every
//!    worker; the poisoned barrier / dropped relay guarantee no hang) and
//!    an engine factory builds a fresh one at the same world
//!    (`--on-failure respawn`) or one rank fewer (`shrink`); `abort`
//!    preserves PR 4's fail-fast contract.
//! 4. **Re-shard + replay** — the snapshot re-imports through the
//!    canonical machinery (exact for elastic codecs at any world) and the
//!    caller rewinds its step loop to the snapshot step. The
//!    deterministic data path + exact token counter make the recovered
//!    run **bitwise identical** to an uninterrupted run launched at the
//!    target world from the same snapshot (pinned in
//!    tests/fault_tolerance.rs).

use crate::checkpoint::canonical::ImportOpts;
use crate::dist::WorkerLoss;
use crate::tensor::Matrix;
use crate::train::{StepEvent, TrainEngine};

/// What to do when a worker rank dies mid-run
/// (`[train] on_failure` / `--on-failure abort|respawn|shrink`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum OnFailure {
    /// Fail the run promptly with the dead rank named (PR 4 behavior).
    #[default]
    Abort,
    /// Rebuild the cluster at the SAME world size and replay from the
    /// snapshot.
    Respawn,
    /// Rebuild at `world - 1` (floor 1) — elastic training on the
    /// surviving capacity — and re-shard the snapshot into it.
    Shrink,
}

impl OnFailure {
    /// Shared by TOML and CLI parsing so the two can never drift.
    pub fn parse(s: &str) -> Result<OnFailure, String> {
        match s {
            "abort" => Ok(OnFailure::Abort),
            "respawn" => Ok(OnFailure::Respawn),
            "shrink" => Ok(OnFailure::Shrink),
            other => Err(format!(
                "unknown on-failure policy {other:?} (abort|respawn|shrink)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OnFailure::Abort => "abort",
            OnFailure::Respawn => "respawn",
            OnFailure::Shrink => "shrink",
        }
    }
}

/// Recovery knobs, bundled so the trainer config maps onto one value.
#[derive(Clone, Copy, Debug)]
pub struct RecoveryPolicy {
    pub on_failure: OnFailure,
    /// Snapshot cadence in steps (0 is treated as 1). Smaller = cheaper
    /// replay after a failure, pricier steady state.
    pub snapshot_every: u64,
    /// Total worker-loss recoveries allowed before the run fails anyway —
    /// a flapping cluster must not loop forever.
    pub max_recoveries: usize,
}

impl Default for RecoveryPolicy {
    fn default() -> RecoveryPolicy {
        RecoveryPolicy {
            on_failure: OnFailure::Abort,
            snapshot_every: 50,
            max_recoveries: 3,
        }
    }
}

/// A rolling in-memory restore point: everything needed to rebuild the
/// run's state on a FRESH cluster of any world size. `step`/`tokens_seen`
/// are the values *before* step `step` ran — resuming means replaying
/// steps `step..`.
#[derive(Clone)]
pub struct Snapshot {
    pub step: u64,
    pub tokens_seen: u64,
    /// Full (unsharded) parameters.
    pub params: Vec<Matrix>,
    /// Canonical (world-agnostic) optimizer bytes
    /// ([`TrainEngine::export_state`]).
    pub opt_state: Vec<u8>,
}

/// What one supervised step produced.
pub enum Supervised {
    /// The step applied normally.
    Stepped,
    /// A worker died; the cluster was rebuilt at `new_world` and restored
    /// from the snapshot. The caller must rewind its loop to
    /// `resume_step`, reset its token counter to `tokens_seen`, and emit
    /// `events` to its observers (in order).
    Recovered {
        resume_step: u64,
        tokens_seen: u64,
        new_world: usize,
        events: Vec<StepEvent>,
    },
}

/// Builds a replacement engine at a given world size. Invoked only after
/// the dead engine has been fully dropped (workers reaped, sockets
/// closed), so respawning at the same world cannot collide with leaked
/// resources.
pub type EngineFactory = Box<dyn FnMut(usize) -> Result<Box<dyn TrainEngine>, String>>;

/// Owns the engine on behalf of a training loop and turns worker deaths
/// into snapshot-restore cycles per its [`RecoveryPolicy`].
pub struct Supervisor {
    /// `None` only transiently inside [`Supervisor::recover`], between
    /// dropping the dead engine and installing its replacement.
    engine: Option<Box<dyn TrainEngine>>,
    factory: EngineFactory,
    policy: RecoveryPolicy,
    /// Import policy for restoring the snapshot into the rebuilt engine
    /// (`--resume-requantize` flows through here like any other import).
    import_opts: ImportOpts,
    snapshot: Option<Snapshot>,
    recoveries: usize,
}

impl Supervisor {
    pub fn new(
        engine: Box<dyn TrainEngine>,
        factory: EngineFactory,
        policy: RecoveryPolicy,
        import_opts: ImportOpts,
    ) -> Supervisor {
        Supervisor {
            engine: Some(engine),
            factory,
            policy,
            import_opts,
            snapshot: None,
            recoveries: 0,
        }
    }

    pub fn engine(&self) -> &dyn TrainEngine {
        self.engine.as_deref().expect("supervisor holds an engine")
    }

    pub fn engine_mut(&mut self) -> &mut dyn TrainEngine {
        self.engine
            .as_deref_mut()
            .expect("supervisor holds an engine")
    }

    pub fn policy(&self) -> &RecoveryPolicy {
        &self.policy
    }

    /// Recoveries performed so far.
    pub fn recoveries(&self) -> usize {
        self.recoveries
    }

    /// Step of the current restore point, if one has been captured.
    pub fn snapshot_step(&self) -> Option<u64> {
        self.snapshot.as_ref().map(|s| s.step)
    }

    /// Whether worker loss is survivable (anything but `abort`).
    pub fn supervising(&self) -> bool {
        self.policy.on_failure != OnFailure::Abort
    }

    /// Capture a restore point if the cadence (or a missing first
    /// snapshot) calls for one. Call at the TOP of the step loop, before
    /// step `t`'s microbatches are drawn: `tokens_seen` must be the
    /// counter value before step `t`. No-op under `--on-failure abort` —
    /// the run would die anyway, so the copies would be pure overhead.
    pub fn maybe_snapshot(&mut self, t: u64, tokens_seen: u64) {
        if !self.supervising() {
            return;
        }
        let due = self.snapshot.is_none() || t % self.policy.snapshot_every.max(1) == 0;
        if !due {
            return;
        }
        let engine = self.engine();
        self.snapshot = Some(Snapshot {
            step: t,
            tokens_seen,
            params: engine.params().to_vec(),
            opt_state: engine.export_state(),
        });
    }

    /// Drive one engine step, converting a worker death into a rebuild +
    /// restore per the policy. `Err` means the run is over: `abort`
    /// policy, recovery budget exhausted, no snapshot yet, or the rebuild
    /// itself failed — every message names the dead rank.
    pub fn step(
        &mut self,
        t: u64,
        per_rank: Vec<Vec<Matrix>>,
        lr: f32,
    ) -> Result<Supervised, String> {
        match self.engine_mut().try_step(t, per_rank, lr) {
            Ok(()) => Ok(Supervised::Stepped),
            Err(loss) => self.recover(t, loss),
        }
    }

    fn recover(&mut self, t: u64, loss: WorkerLoss) -> Result<Supervised, String> {
        let old_world = self.engine().world();
        if !self.supervising() {
            return Err(format!(
                "worker rank {} died at step {t}: {} (--on-failure abort)",
                loss.rank, loss.cause
            ));
        }
        if self.recoveries >= self.policy.max_recoveries {
            return Err(format!(
                "worker rank {} died at step {t}: {} — recovery budget exhausted \
                 ({} of max {})",
                loss.rank, loss.cause, self.recoveries, self.policy.max_recoveries
            ));
        }
        let Some(snap) = self.snapshot.clone() else {
            return Err(format!(
                "worker rank {} died at step {t}: {} — no snapshot captured yet",
                loss.rank, loss.cause
            ));
        };
        self.recoveries += 1;
        let new_world = match self.policy.on_failure {
            OnFailure::Respawn => old_world,
            OnFailure::Shrink => (old_world - 1).max(1),
            OnFailure::Abort => unreachable!("abort handled above"),
        };
        let mut events = vec![
            StepEvent::WorkerLost {
                step: t,
                rank: loss.rank,
                cause: loss.cause.clone(),
            },
            StepEvent::RecoveryStarted {
                from_step: snap.step,
                old_world,
                new_world,
            },
        ];
        // Tear the dead cluster down BEFORE building its replacement: its
        // Drop joins/reaps every worker (the poisoned barrier / dropped
        // relay guarantee none is stuck in a collective), so the new world
        // starts from a clean slate of threads, processes, and sockets.
        drop(self.engine.take());
        let mut engine = (self.factory)(new_world)
            .map_err(|e| format!("rebuilding cluster at world {new_world}: {e}"))?;
        engine.init_params(&snap.params);
        engine
            .import_state_with(&snap.opt_state, self.import_opts)
            .map_err(|e| format!("re-sharding snapshot into world {new_world}: {e}"))?;
        self.engine = Some(engine);
        events.push(StepEvent::RecoveryComplete {
            resume_step: snap.step,
            world: new_world,
        });
        Ok(Supervised::Recovered {
            resume_step: snap.step,
            tokens_seen: snap.tokens_seen,
            new_world,
            events,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn on_failure_parses_and_rejects() {
        assert_eq!(OnFailure::parse("abort").unwrap(), OnFailure::Abort);
        assert_eq!(OnFailure::parse("respawn").unwrap(), OnFailure::Respawn);
        assert_eq!(OnFailure::parse("shrink").unwrap(), OnFailure::Shrink);
        for v in [OnFailure::Abort, OnFailure::Respawn, OnFailure::Shrink] {
            assert_eq!(OnFailure::parse(v.name()).unwrap(), v);
        }
        let err = OnFailure::parse("retry").unwrap_err();
        assert!(err.contains("abort|respawn|shrink"), "unhelpful: {err}");
    }
}
