//! Step-event observer API: consumers subscribe to the trainer's event
//! stream instead of reaching into trainer internals.
//!
//! The trainer emits [`StepEvent`]s at its logging cadence (`log_every`
//! for train points, `eval_every` for validation sweeps, and one event per
//! checkpoint). [`crate::metrics::Metrics`] is itself an observer — the
//! loss curves every bench and the coordinator read are built from the
//! same stream external observers see.

use std::path::PathBuf;

/// One trainer lifecycle event.
#[derive(Clone, Debug)]
pub enum StepEvent {
    /// A training step completed (emitted at the `log_every` cadence and
    /// on the final step).
    Train {
        step: u64,
        loss: f64,
        lr: f64,
        tokens_seen: u64,
        wall_secs: f64,
    },
    /// A validation sweep completed (`eval_every` cadence).
    Val {
        step: u64,
        loss: f64,
        lr: f64,
        tokens_seen: u64,
        wall_secs: f64,
    },
    /// A checkpoint was written.
    Checkpoint { step: u64, path: PathBuf },
}

/// Subscriber to the trainer's event stream; register with
/// [`crate::train::Trainer::add_observer`].
pub trait StepObserver {
    fn on_event(&mut self, event: &StepEvent);
}
