//! Step-event observer API: consumers subscribe to the trainer's event
//! stream instead of reaching into trainer internals.
//!
//! The trainer emits [`StepEvent`]s at its logging cadence (`log_every`
//! for train points, `eval_every` for validation sweeps, and one event per
//! checkpoint). [`crate::metrics::Metrics`] is itself an observer — the
//! loss curves every bench and the coordinator read are built from the
//! same stream external observers see.
//!
//! One event is a per-step *firehose*: [`StepEvent::StepTimed`] fires on
//! EVERY distributed step (not just at `log_every`), carrying the step's
//! comm/compute split so benches and dashboards stop hand-rolling their
//! own timing around `Cluster::step`.

use std::path::PathBuf;

/// One trainer lifecycle event.
#[derive(Clone, Debug)]
pub enum StepEvent {
    /// A training step completed (emitted at the `log_every` cadence and
    /// on the final step).
    Train {
        step: u64,
        loss: f64,
        lr: f64,
        tokens_seen: u64,
        wall_secs: f64,
    },
    /// A validation sweep completed (`eval_every` cadence).
    Val {
        step: u64,
        loss: f64,
        lr: f64,
        tokens_seen: u64,
        wall_secs: f64,
    },
    /// Per-step timing firehose: emitted on EVERY distributed step
    /// (single-process mode has no cluster and emits none). `comm_ns` is
    /// the slowest rank's worker-blocked collective time — with overlapped
    /// collectives this is the *un-hidden* comm cost; `compute_ns` is the
    /// rest of that rank's step wall time. Observability only: values are
    /// wall-clock and NOT deterministic, so nothing downstream may feed
    /// them back into training decisions.
    StepTimed {
        step: u64,
        comm_ns: u64,
        compute_ns: u64,
    },
    /// Per-step data-plane firehose: emitted on EVERY distributed step
    /// alongside [`StepEvent::StepTimed`]. `socket_bytes`/`shm_bytes` are
    /// the step's payload bytes summed across ranks (socket frames vs the
    /// shared-memory slot table — both zero under the thread transport,
    /// which moves no bytes); `peak_transient` is the largest rank's
    /// transient-buffer footprint. Observability only.
    StepTraffic {
        step: u64,
        socket_bytes: u64,
        shm_bytes: u64,
        peak_transient: u64,
    },
    /// A checkpoint was written.
    Checkpoint { step: u64, path: PathBuf },
    /// A worker rank died mid-run (`step` is the step being served when
    /// the loss was detected; `rank`/`cause` name the rank that failed
    /// first, not the first victim observed).
    WorkerLost { step: u64, rank: usize, cause: String },
    /// Recovery began: the dead cluster is torn down and rebuilt at
    /// `new_world`, then state re-shards from the step-`from_step`
    /// snapshot (`--on-failure respawn` keeps `new_world == old_world`;
    /// `shrink` reduces it).
    RecoveryStarted {
        from_step: u64,
        old_world: usize,
        new_world: usize,
    },
    /// Recovery finished: training resumes at `resume_step` on a healthy
    /// `world`-rank cluster (replaying `resume_step..` from the snapshot).
    RecoveryComplete { resume_step: u64, world: usize },
}

/// Subscriber to the trainer's event stream; register with
/// [`crate::train::Trainer::add_observer`].
pub trait StepObserver {
    fn on_event(&mut self, event: &StepEvent);
}
