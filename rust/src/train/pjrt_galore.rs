//! GaLore optimizer whose fused update runs the L1 Pallas kernel via PJRT.
//!
//! The three-layer story on the *optimizer* hot path: the subspace refresh
//! (randomized SVD) stays in Rust, but the per-step work — low-rank Adam
//! moment update + α·P·N reprojection — executes the
//! `galore_update_<d>x<n>x<r>.hlo.txt` artifact lowered from
//! python/compile/kernels/galore_update.py. Numerically interchangeable
//! with the native engine (tested in rust/tests/); the `--engine pjrt`
//! flag switches between them.
//!
//! Kernel orientation: artifacts are lowered for (dim=min(m,n), n=max(m,n))
//! per Alg. 1's min-side projection; tall parameters are handled by
//! transposing the gradient in and the delta out.

use crate::linalg::{randomized_svd, RandSvdOpts};
use crate::optim::{AdamCfg, GaLoreCfg, Optimizer};
use crate::runtime::{Executable, HostTensor, Manifest, Runtime};
use crate::tensor::Matrix;
use crate::util::rng::Pcg64;
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

struct ParamState {
    /// P (dim × rank), dim = min side of the parameter.
    p: Matrix,
    m: Matrix,
    v: Matrix,
    /// Parameter is stored (rows, cols); kernel runs on the (dim, n) view —
    /// transposed when rows > cols.
    transposed: bool,
    exe: Arc<Executable>,
    last_refresh: u64,
}

pub struct PjrtGaLore {
    cfg: GaLoreCfg,
    adam: AdamCfg,
    rt: Arc<Runtime>,
    artifacts_dir: PathBuf,
    manifest: Manifest,
    states: BTreeMap<usize, ParamState>,
    /// Full-rank fallback for ineligible params (runs natively; the model's
    /// norm vectors are noise-level cost).
    fallback: crate::optim::AdamW,
    rng: Pcg64,
    t: u64,
}

impl PjrtGaLore {
    pub fn new(
        cfg: GaLoreCfg,
        adam: AdamCfg,
        rt: Arc<Runtime>,
        artifacts_dir: PathBuf,
        manifest: Manifest,
        seed: u64,
    ) -> PjrtGaLore {
        PjrtGaLore {
            cfg,
            adam,
            rt,
            artifacts_dir,
            manifest,
            states: BTreeMap::new(),
            fallback: crate::optim::AdamW::new(adam),
            // Same stream constant as the native GaLore so both engines
            // draw identical randomized-SVD sketches from the same seed
            // (the engine-parity test relies on it).
            rng: Pcg64::new(seed, 0x6a10),
            t: 0,
        }
    }

    fn eligible(&self, rows: usize, cols: usize) -> bool {
        rows.min(cols) > self.cfg.rank && rows >= 2 && cols >= 2
    }

    /// Compute P from the gradient's min-side singular vectors.
    fn compute_p(&mut self, grad_view: &Matrix) -> Matrix {
        // grad_view is already (dim, n) with dim ≤ n ⇒ left side.
        let svd = randomized_svd(
            grad_view,
            self.cfg.rank,
            RandSvdOpts::default(),
            &mut self.rng,
        );
        svd.u.first_cols(self.cfg.rank)
    }

    fn load_kernel(&self, dim: usize, n: usize) -> Result<Arc<Executable>> {
        let entry = self
            .manifest
            .kernel_for(dim, n, self.cfg.rank)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no galore_update kernel artifact for ({dim},{n},{}) — \
                     run `make artifacts` with --kernels",
                    self.cfg.rank
                )
            })?;
        self.rt.load(self.artifacts_dir.join(&entry.file))
    }
}

impl Optimizer for PjrtGaLore {
    fn begin_step(&mut self, t: u64) {
        self.t = t;
        self.fallback.begin_step(t);
    }

    fn step_param(&mut self, idx: usize, param: &mut Matrix, grad: &Matrix, lr: f32) {
        let (rows, cols) = param.shape();
        if !self.eligible(rows, cols) {
            self.fallback.step_param(idx, param, grad, lr);
            return;
        }
        let transposed = rows > cols;
        let grad_view = if transposed { grad.transpose() } else { grad.clone() };
        let (dim, n) = grad_view.shape();

        if !self.states.contains_key(&idx) {
            let p = self.compute_p(&grad_view);
            let exe = self.load_kernel(dim, n).expect("kernel artifact");
            self.states.insert(
                idx,
                ParamState {
                    p,
                    m: Matrix::zeros(self.cfg.rank, n),
                    v: Matrix::zeros(self.cfg.rank, n),
                    transposed,
                    exe,
                    last_refresh: self.t,
                },
            );
        } else if self.t % self.cfg.update_freq == 0
            && self.states[&idx].last_refresh != self.t
        {
            let p = self.compute_p(&grad_view);
            let st = self.states.get_mut(&idx).unwrap();
            st.p = p;
            st.last_refresh = self.t;
        }

        let st = self.states.get_mut(&idx).unwrap();
        debug_assert_eq!(st.transposed, transposed);
        // R = Pᵀ G (native BLAS3 — cheap relative to the fused kernel).
        let r = st.p.matmul_at_b(&grad_view);
        // Fused Adam + reproject on the device.
        let out = st
            .exe
            .run(&[
                HostTensor::from_matrix(&st.p),
                HostTensor::from_matrix(&r),
                HostTensor::from_matrix(&st.m),
                HostTensor::from_matrix(&st.v),
                HostTensor::scalar_f32(self.t as f32),
            ])
            .expect("galore_update kernel execution");
        st.m.data.copy_from_slice(&out[0]);
        st.v.data.copy_from_slice(&out[1]);
        // delta (dim, n), alpha applied host-side (artifact bakes α=1).
        let scale = lr * self.cfg.alpha;
        if self.adam.weight_decay > 0.0 {
            let wd = self.adam.weight_decay;
            for x in param.data.iter_mut() {
                *x -= lr * wd * *x;
            }
        }
        if transposed {
            // delta is (dim=cols, n=rows): apply transposed.
            for r_i in 0..rows {
                for c_i in 0..cols {
                    param.data[r_i * cols + c_i] -= scale * out[2][c_i * rows + r_i];
                }
            }
        } else {
            for (w, d) in param.data.iter_mut().zip(&out[2]) {
                *w -= scale * d;
            }
        }
    }

    fn state_bytes(&self) -> usize {
        self.fallback.state_bytes()
            + self
                .states
                .values()
                .map(|s| (s.p.numel() + s.m.numel() + s.v.numel()) * 4)
                .sum::<usize>()
    }

    fn name(&self) -> &'static str {
        "galore-pjrt"
    }

    fn import_state(&mut self, _bytes: &[u8]) -> Result<(), String> {
        // No host snapshot format for the kernel-resident state yet; fail
        // loudly rather than resuming with silently-reset moments (the
        // trait default would return Ok and diverge the trajectory).
        Err("galore-pjrt cannot restore optimizer state yet — resume with \
             --engine native"
            .into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{GaLore, ProjectionKind};

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn setup() -> Option<(Arc<Runtime>, Manifest)> {
        let mp = artifacts_dir().join("manifest_llama-nano.json");
        if !mp.exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        let manifest = Manifest::load(mp).unwrap();
        if manifest.kernels.is_empty() {
            return None;
        }
        Some((Arc::new(Runtime::cpu().unwrap()), manifest))
    }

    #[test]
    fn pjrt_engine_matches_native_engine() {
        // Same trajectory as the native GaLore when both use the same P.
        // We pin the subspace by using a rank-r target and FullSvd-free
        // determinism: feed identical gradients and compare updates.
        let Some((rt, manifest)) = setup() else { return };
        let cfg = GaLoreCfg {
            rank: 16,
            update_freq: 1_000_000, // refresh only at init
            alpha: 0.25,
            projection: ProjectionKind::RandSvd,
            ..GaLoreCfg::default()
        };
        let adam = AdamCfg::default();
        let mut pjrt = PjrtGaLore::new(
            cfg,
            adam,
            rt,
            artifacts_dir(),
            manifest,
            7,
        );
        let mut native = GaLore::new(cfg, adam, 7); // same seed ⇒ same rand-SVD
        let mut rng = Pcg64::new(3, 0);
        let target = Matrix::randn(64, 176, 0.5, &mut rng);
        let mut wp = Matrix::zeros(64, 176);
        let mut wn = Matrix::zeros(64, 176);
        for t in 0..10 {
            let gp = wp.sub(&target);
            let gn = wn.sub(&target);
            pjrt.begin_step(t);
            pjrt.step_param(0, &mut wp, &gp, 0.05);
            native.begin_step(t);
            native.step_param(0, &mut wn, &gn, 0.05);
        }
        let diff = crate::testing::prop::max_abs_diff(&wp.data, &wn.data);
        assert!(diff < 1e-4, "pjrt vs native drift {diff}");
    }

    #[test]
    fn transposed_param_handled() {
        let Some((rt, manifest)) = setup() else { return };
        let cfg = GaLoreCfg {
            rank: 16,
            update_freq: 1_000_000,
            alpha: 1.0,
            ..GaLoreCfg::default()
        };
        let mut opt = PjrtGaLore::new(
            cfg,
            AdamCfg::default(),
            rt,
            artifacts_dir(),
            manifest,
            9,
        );
        let mut rng = Pcg64::new(4, 0);
        // 176×64 (tall) — kernel exists only as (64, 176, 16). Rank-16
        // target keeps the optimum inside the projected subspace.
        let a = Matrix::randn(176, 16, 0.5, &mut rng);
        let b = Matrix::randn(16, 64, 0.5, &mut rng);
        let target = a.matmul(&b);
        let mut w = Matrix::zeros(176, 64);
        let before = target.frobenius_norm();
        for t in 0..100 {
            let g = w.sub(&target);
            opt.begin_step(t);
            opt.step_param(0, &mut w, &g, 0.1);
        }
        let after = w.sub(&target).frobenius_norm();
        assert!(after < before * 0.2, "{before} -> {after}");
    }

    #[test]
    fn small_params_use_fallback() {
        let Some((rt, manifest)) = setup() else { return };
        let cfg = GaLoreCfg {
            rank: 16,
            ..GaLoreCfg::default()
        };
        let mut opt = PjrtGaLore::new(
            cfg,
            AdamCfg::default(),
            rt,
            artifacts_dir(),
            manifest,
            1,
        );
        let mut p = Matrix::zeros(1, 64);
        let g = Matrix::from_vec(1, 64, vec![1.0; 64]);
        opt.begin_step(0);
        opt.step_param(0, &mut p, &g, 0.1);
        assert!(p.max_abs() > 0.0);
        assert_eq!(opt.state_bytes(), 2 * 64 * 4); // fallback adam moments
    }
}
