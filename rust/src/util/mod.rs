//! Shared substrates: RNG, CLI parsing, JSON/TOML codecs, formatting.
//!
//! These exist because the build is fully offline (only the crates vendored
//! for the `xla` bridge are available) — see DESIGN.md §3 item 7.

pub mod cli;
pub mod json;
pub mod rng;
pub mod toml;

use std::time::{Duration, Instant};

/// A simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer {
            start: Instant::now(),
        }
    }
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }
}

/// Format a byte count with binary units ("72.84 GiB").
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut x = bytes as f64;
    let mut u = 0;
    while x >= 1024.0 && u + 1 < UNITS.len() {
        x /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{x:.2} {}", UNITS[u])
    }
}

/// Format a count with SI suffixes ("6.5M", "500B" tokens).
pub fn human_count(n: u64) -> String {
    const UNITS: [(&str, f64); 4] = [("T", 1e12), ("B", 1e9), ("M", 1e6), ("K", 1e3)];
    for (suffix, scale) in UNITS {
        if n as f64 >= scale {
            return format!("{:.2}{suffix}", n as f64 / scale);
        }
    }
    n.to_string()
}

/// Format a duration compactly ("1.2s", "35ms", "2m03s").
pub fn human_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 60.0 {
        format!("{}m{:04.1}s", (s / 60.0) as u64, s % 60.0)
    } else if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}µs", s * 1e6)
    }
}

/// Mean and sample standard deviation of a slice.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
    (mean, var.sqrt())
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(78_209_720_320), "72.84 GiB");
    }

    #[test]
    fn count_formatting() {
        assert_eq!(human_count(999), "999");
        assert_eq!(human_count(6_500_000), "6.50M");
        assert_eq!(human_count(500_000_000_000), "500.00B");
    }

    #[test]
    fn stats() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let (m, s) = mean_std(&xs);
        assert!((m - 2.5).abs() < 1e-12);
        assert!((s - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
    }
}
