//! Minimal JSON encoder/decoder.
//!
//! Offline build: no serde available, so metrics emission and the artifact
//! manifest use this self-contained codec. It supports the full JSON data
//! model; numbers are f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) for stable output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("set on non-object Json");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    pub fn from_f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(input: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain bytes (handles UTF-8 transparently).
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos]).map_err(
                        |e| format!("invalid utf-8 in string: {e}"),
                    )?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] found {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} found {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2,{"b":"x\ny","c":null}],"d":{"e":true}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "x\ny"
        );
    }

    #[test]
    fn builder_api() {
        let mut j = Json::obj();
        j.set("step", Json::num(5.0))
            .set("loss", Json::num(2.25))
            .set("tag", Json::str("train"));
        let s = j.to_string();
        assert_eq!(s, r#"{"loss":2.25,"step":5,"tag":"train"}"#);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""été""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "été");
    }

    #[test]
    fn pretty_parses_back() {
        let src = r#"{"a":[1,2],"b":{"c":3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }
}
