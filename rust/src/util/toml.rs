//! TOML-subset parser for configuration files.
//!
//! Supports the subset used by `configs/*.toml`: top-level and `[section]`
//! tables, string / integer / float / boolean / string-array values, and
//! `#` comments. Nested tables beyond one level and inline tables are not
//! needed and rejected explicitly.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    StrArr(Vec<String>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(x) => Some(*x as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed TOML document: `section -> key -> value`. Top-level keys live in
/// the "" section.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(input: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut current = String::new();
        doc.sections.entry(current.clone()).or_default();

        for (lineno, raw) in input.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section header", lineno + 1))?
                    .trim();
                if name.is_empty() || name.contains('[') {
                    return Err(format!("line {}: bad section name {name:?}", lineno + 1));
                }
                current = name.to_string();
                doc.sections.entry(current.clone()).or_default();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            doc.sections
                .get_mut(&current)
                .unwrap()
                .insert(key.to_string(), val);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section).and_then(|m| m.get(key))
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn i64_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let end = inner.rfind('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(inner[..end].to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if s.starts_with('[') {
        let inner = s
            .strip_prefix('[')
            .and_then(|x| x.strip_suffix(']'))
            .ok_or("unterminated array")?;
        let mut items = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            match parse_value(part)? {
                TomlValue::Str(x) => items.push(x),
                other => return Err(format!("only string arrays supported, got {other:?}")),
            }
        }
        return Ok(TomlValue::StrArr(items));
    }
    if s.starts_with('{') {
        return Err("inline tables not supported".into());
    }
    let clean = s.replace('_', "");
    if !clean.contains('.') && !clean.contains('e') && !clean.contains('E') {
        if let Ok(x) = clean.parse::<i64>() {
            return Ok(TomlValue::Int(x));
        }
    }
    clean
        .parse::<f64>()
        .map(TomlValue::Float)
        .map_err(|_| format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# a config
name = "llama-mini"   # trailing comment
steps = 1_000
lr = 2.5e-3
use_fsdp = true

[galore]
rank = 64
alpha = 0.125
projection = "rand_svd"
tags = ["a", "b"]
"#;

    #[test]
    fn parses_sample() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.str_or("", "name", "?"), "llama-mini");
        assert_eq!(doc.i64_or("", "steps", 0), 1000);
        assert!((doc.f64_or("", "lr", 0.0) - 2.5e-3).abs() < 1e-12);
        assert!(doc.bool_or("", "use_fsdp", false));
        assert_eq!(doc.i64_or("galore", "rank", 0), 64);
        assert_eq!(doc.str_or("galore", "projection", "?"), "rand_svd");
        assert_eq!(
            doc.get("galore", "tags").unwrap(),
            &TomlValue::StrArr(vec!["a".into(), "b".into()])
        );
    }

    #[test]
    fn int_vs_float() {
        let doc = TomlDoc::parse("a = 3\nb = 3.0\n").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_i64(), Some(3));
        assert_eq!(doc.get("", "b").unwrap().as_i64(), None);
        assert_eq!(doc.get("", "b").unwrap().as_f64(), Some(3.0));
        // ints coerce to f64 on request
        assert_eq!(doc.get("", "a").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = TomlDoc::parse("x = \"a#b\"\n").unwrap();
        assert_eq!(doc.str_or("", "x", ""), "a#b");
    }

    #[test]
    fn errors_are_reported_with_line() {
        let err = TomlDoc::parse("ok = 1\nbroken\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        assert!(TomlDoc::parse("[unterminated\n").is_err());
        assert!(TomlDoc::parse("x = {a=1}\n").is_err());
    }

    #[test]
    fn defaults_apply() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(doc.i64_or("nope", "k", 7), 7);
    }
}
