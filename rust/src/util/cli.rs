//! Command-line argument parsing.
//!
//! Offline build: no clap, so the launcher uses this small flag parser.
//! Syntax: `galore2 <subcommand> [--flag value] [--flag=value] [--switch]`.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    /// Flags consumed via accessors; used by `check_unused`.
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: remaining tokens are positional.
                    args.positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.flags.insert(body.to_string(), v);
                } else {
                    args.switches.push(body.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    pub fn has(&self, key: &str) -> bool {
        self.mark(key);
        self.switches.iter().any(|s| s == key) || self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.parse_or(key, default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.parse_or(key, default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.parse_or(key, default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.parse_or(key, default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.mark(key);
        if self.switches.iter().any(|s| s == key) {
            return true;
        }
        match self.flags.get(key).map(|s| s.as_str()) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) | None => default,
        }
    }

    fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.get(key) {
            Some(s) => s.parse::<T>().unwrap_or_else(|_| {
                eprintln!("warning: cannot parse --{key} {s:?}; using default");
                default
            }),
            None => default,
        }
    }

    /// Return flags the program never queried — typo detection for users.
    pub fn unused(&self) -> Vec<String> {
        let seen = self.seen.borrow();
        self.flags
            .keys()
            .chain(self.switches.iter())
            .filter(|k| !seen.contains(k))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --config configs/mini.toml --steps 100 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.str_or("config", ""), "configs/mini.toml");
        assert_eq!(a.usize_or("steps", 0), 100);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("bench --rank=128 --alpha=0.25");
        assert_eq!(a.usize_or("rank", 0), 128);
        assert!((a.f32_or("alpha", 0.0) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn bool_flags() {
        let a = parse("x --fsdp true --debug --trace=false");
        assert!(a.bool_or("fsdp", false));
        assert!(a.bool_or("debug", false));
        assert!(!a.bool_or("trace", true));
        assert!(a.bool_or("absent", true));
    }

    #[test]
    fn positional_after_subcommand() {
        let a = parse("eval ckpt1 ckpt2 --suite all");
        assert_eq!(a.subcommand.as_deref(), Some("eval"));
        assert_eq!(a.positional, vec!["ckpt1", "ckpt2"]);
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse("run -- --not-a-flag");
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }

    #[test]
    fn unused_detection() {
        let a = parse("train --steps 5 --typo 3");
        let _ = a.usize_or("steps", 0);
        assert_eq!(a.unused(), vec!["typo".to_string()]);
    }

    #[test]
    fn defaults_on_parse_failure() {
        let a = parse("x --steps abc");
        assert_eq!(a.usize_or("steps", 7), 7);
    }
}
