//! Deterministic pseudo-random number generation.
//!
//! The whole framework is seeded: every worker, every data shard and every
//! projector refresh derives its stream from a root seed via `split`, so
//! distributed runs are bit-reproducible regardless of thread scheduling.
//! The generator is PCG64 (O'Neill 2014), chosen for quality + tiny state.

/// PCG-XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different stream ids
    /// yield statistically independent sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Derive a child generator. `tag` namespaces the child (e.g. worker
    /// rank, layer index) so sibling children are independent.
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let s = self.next_u64();
        Pcg64::new(s ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15), tag)
    }

    /// Snapshot the generator's exact position (checkpoint serialization —
    /// resuming a run must continue the stream, not restart it).
    pub fn state_bits(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator at an exact position captured by
    /// [`Pcg64::state_bits`].
    pub fn from_state_bits(state: u128, inc: u128) -> Pcg64 {
        Pcg64 { state, inc }
    }

    /// Exact size of a serialized generator position.
    pub const STATE_BYTES: usize = 32;

    /// Append the generator position as [`Pcg64::STATE_BYTES`]
    /// little-endian bytes. The single serialization format for every
    /// state blob that carries an RNG position (GaLore optimizer state,
    /// FSDP worker state).
    pub fn write_state(&self, out: &mut Vec<u8>) {
        // lint: allow(single-parser): fixed 32-byte Pcg64 snapshot; routing through optim::ser would invert the util→optim layering
        out.extend_from_slice(&self.state.to_le_bytes());
        // lint: allow(single-parser): second half of the same fixed-width snapshot
        out.extend_from_slice(&self.inc.to_le_bytes());
    }

    /// Rebuild from bytes written by [`Pcg64::write_state`].
    pub fn read_state(bytes: &[u8]) -> Result<Pcg64, String> {
        if bytes.len() < Self::STATE_BYTES {
            return Err("truncated rng state".into());
        }
        Ok(Pcg64 {
            // lint: allow(single-parser): fixed 32-byte Pcg64 snapshot, length-checked above; avoids util→optim layering inversion
            state: u128::from_le_bytes(bytes[0..16].try_into().unwrap()),
            // lint: allow(single-parser): second half of the same length-checked snapshot
            inc: u128::from_le_bytes(bytes[16..32].try_into().unwrap()),
        })
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // 24 high-quality mantissa bits.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via Box-Muller (cached second value dropped for
    /// simplicity; the generator is cheap).
    pub fn next_normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Fill a slice with N(0, std^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.next_normal() * std;
        }
    }

    /// Fill a slice with U[lo, hi) samples.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = lo + (hi - lo) * self.next_f32();
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn sample_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 0);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_children_independent() {
        let mut root = Pcg64::new(7, 0);
        let mut c0 = root.split(0);
        let mut c1 = root.split(1);
        let same = (0..64).filter(|_| c0.next_u64() == c1.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn state_bits_roundtrip_continues_the_stream() {
        let mut a = Pcg64::new(42, 3);
        for _ in 0..17 {
            a.next_u64();
        }
        let (state, inc) = a.state_bits();
        let mut b = Pcg64::from_state_bits(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn serialized_state_roundtrip_continues_the_stream() {
        let mut a = Pcg64::new(9, 1);
        for _ in 0..5 {
            a.next_u64();
        }
        let mut buf = Vec::new();
        a.write_state(&mut buf);
        assert_eq!(buf.len(), Pcg64::STATE_BYTES);
        let mut b = Pcg64::read_state(&buf).unwrap();
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert!(Pcg64::read_state(&buf[..31]).is_err());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg64::new(1, 0);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Pcg64::new(3, 0);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.next_below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11, 0);
        let n = 50_000;
        let (mut sum, mut sq) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.next_normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(5, 0);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_sampling_respects_weights() {
        let mut r = Pcg64::new(9, 0);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.sample_weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}
