//! PJRT runtime: load AOT artifacts and execute them from the hot path.
//!
//! Python is build-time only; the coordinator talks to XLA through this
//! module. Artifacts are HLO *text* (see python/compile/aot.py for why),
//! parsed + compiled once per process and cached by path.
//!
//! `Runtime` wraps the PJRT CPU client; `Executable::run` moves host
//! tensors (f32 matrices / i32 token grids) in as literals and returns
//! every tuple element as an f32 vector.

mod manifest;
mod xla_stub;

pub use manifest::{KernelEntry, Manifest, ParamSpec};

// Dependency-light build: the `xla` name resolves to the in-repo stub. Link
// the real xla-rs crate by swapping this alias (see xla_stub.rs docs).
use xla_stub as xla;

use crate::tensor::Matrix;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// A host-side input tensor.
#[derive(Clone, Debug)]
pub enum HostTensor {
    /// Row-major f32 with explicit dims (e.g. `[rows, cols]` or `[n]`).
    F32(Vec<f32>, Vec<i64>),
    /// Row-major i32 (token grids).
    I32(Vec<i32>, Vec<i64>),
}

impl HostTensor {
    pub fn from_matrix(m: &Matrix) -> HostTensor {
        HostTensor::F32(m.data.clone(), vec![m.rows as i64, m.cols as i64])
    }

    /// 1-d f32 (norm weights lower as rank-1 in the model ABI).
    pub fn from_vec1(v: &[f32]) -> HostTensor {
        HostTensor::F32(v.to_vec(), vec![v.len() as i64])
    }

    pub fn scalar_f32(x: f32) -> HostTensor {
        HostTensor::F32(vec![x], vec![])
    }

    pub fn tokens(data: &[i32], batch: usize, seq: usize) -> HostTensor {
        assert_eq!(data.len(), batch * seq);
        HostTensor::I32(data.to_vec(), vec![batch as i64, seq as i64])
    }

    pub fn numel(&self) -> usize {
        match self {
            HostTensor::F32(d, _) => d.len(),
            HostTensor::I32(d, _) => d.len(),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            HostTensor::F32(data, dims) => {
                if dims.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    xla::Literal::vec1(data).reshape(dims)?
                }
            }
            HostTensor::I32(data, dims) => xla::Literal::vec1(data).reshape(dims)?,
        })
    }
}

/// Process-wide PJRT client (the "device").
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, std::sync::Arc<Executable>>>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached by canonical path).
    pub fn load(&self, path: impl AsRef<Path>) -> Result<std::sync::Arc<Executable>> {
        let canonical = path
            .as_ref()
            .canonicalize()
            .with_context(|| format!("artifact not found: {:?}", path.as_ref()))?;
        if let Some(hit) = self.cache.lock().unwrap().get(&canonical) {
            return Ok(hit.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            canonical.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {:?}", canonical))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {:?}", canonical))?;
        let arc = std::sync::Arc::new(Executable {
            exe,
            path: canonical.clone(),
        });
        self.cache.lock().unwrap().insert(canonical, arc.clone());
        Ok(arc)
    }
}

/// A compiled computation.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    path: PathBuf,
}

impl Executable {
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Execute with the given inputs; returns each output-tuple element as
    /// a flat f32 vector (all our artifact outputs are f32).
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True, so outputs are one tuple.
        let elems = result.to_tuple()?;
        elems
            .into_iter()
            .enumerate()
            .map(|(i, lit)| {
                lit.to_vec::<f32>()
                    .with_context(|| format!("output {i} of {:?} not f32", self.path))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn nano_available() -> bool {
        artifacts_dir().join("model_llama-nano.hlo.txt").exists()
    }

    #[test]
    fn executes_nano_fwd_bwd_artifact() {
        if !nano_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let manifest =
            Manifest::load(artifacts_dir().join("manifest_llama-nano.json")).unwrap();
        let exe = rt
            .load(artifacts_dir().join(&manifest.artifacts["fwd_bwd"]))
            .unwrap();
        let mut rng = crate::util::rng::Pcg64::new(1, 0);
        let mut inputs: Vec<HostTensor> = manifest
            .params
            .iter()
            .map(|p| {
                let numel: usize = p.shape.iter().product();
                if p.shape.len() == 1 {
                    // norm weights start at 1
                    HostTensor::F32(vec![1.0; numel], vec![numel as i64])
                } else {
                    let mut data = vec![0f32; numel];
                    rng.fill_normal(&mut data, 0.02);
                    HostTensor::F32(data, p.shape.iter().map(|&d| d as i64).collect())
                }
            })
            .collect();
        let toks: Vec<i32> = (0..manifest.batch * manifest.seq)
            .map(|i| (i % manifest.vocab) as i32)
            .collect();
        inputs.push(HostTensor::tokens(&toks, manifest.batch, manifest.seq));
        inputs.push(HostTensor::tokens(&toks, manifest.batch, manifest.seq));
        let out = exe.run(&inputs).unwrap();
        assert_eq!(out.len(), 1 + manifest.params.len());
        let loss = out[0][0];
        // Untrained model ⇒ loss ≈ ln(vocab).
        let expect = (manifest.vocab as f32).ln();
        assert!(
            (loss - expect).abs() < 1.0,
            "loss {loss} vs ln(vocab) {expect}"
        );
        // Gradients shaped like parameters, finite, non-trivial.
        for (i, p) in manifest.params.iter().enumerate() {
            let g = &out[i + 1];
            assert_eq!(g.len(), p.shape.iter().product::<usize>(), "{}", p.name);
            assert!(g.iter().all(|x| x.is_finite()), "{} has non-finite", p.name);
        }
    }

    #[test]
    fn executable_cache_dedups() {
        if !nano_available() {
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let p = artifacts_dir().join("model_llama-nano.hlo.txt");
        let a = rt.load(&p).unwrap();
        let b = rt.load(&p).unwrap();
        assert!(std::sync::Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn galore_update_kernel_matches_native() {
        // The Pallas kernel artifact must agree with the Rust-native GaLore
        // math — the cross-layer correctness link (L1 ⇄ L3).
        if !artifacts_dir()
            .join("galore_update_64x176x16.hlo.txt")
            .exists()
        {
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let exe = rt
            .load(artifacts_dir().join("galore_update_64x176x16.hlo.txt"))
            .unwrap();
        let (dim, n, r) = (64usize, 176usize, 16usize);
        let mut rng = crate::util::rng::Pcg64::new(2, 0);
        let p = Matrix::randn(dim, r, 1.0, &mut rng);
        let rr = Matrix::randn(r, n, 1.0, &mut rng);
        let m = Matrix::randn(r, n, 0.1, &mut rng);
        let mut v = Matrix::randn(r, n, 0.1, &mut rng);
        for x in v.data.iter_mut() {
            *x = x.abs();
        }
        let t = 7.0f32;
        let out = exe
            .run(&[
                HostTensor::from_matrix(&p),
                HostTensor::from_matrix(&rr),
                HostTensor::from_matrix(&m),
                HostTensor::from_matrix(&v),
                HostTensor::scalar_f32(t),
            ])
            .unwrap();
        assert_eq!(out.len(), 3);
        // Native recompute (alpha baked to 1.0 in the artifact; the Rust
        // engine applies the configured alpha outside the kernel).
        let (b1, b2, eps, alpha) = (0.9f32, 0.999f32, 1e-8f32, 1.0f32);
        let mut new_m = vec![0f32; r * n];
        let mut new_v = vec![0f32; r * n];
        let mut n_hat = Matrix::zeros(r, n);
        let bc1 = 1.0 - b1.powf(t + 1.0);
        let bc2 = 1.0 - b2.powf(t + 1.0);
        for i in 0..r * n {
            new_m[i] = b1 * m.data[i] + (1.0 - b1) * rr.data[i];
            new_v[i] = b2 * v.data[i] + (1.0 - b2) * rr.data[i] * rr.data[i];
            n_hat.data[i] = (new_m[i] / bc1) / ((new_v[i] / bc2).sqrt() + eps);
        }
        let mut delta = p.matmul(&n_hat);
        delta.scale(alpha);
        crate::testing::prop::assert_close(&out[0], &new_m, 1e-5, 1e-4).unwrap();
        crate::testing::prop::assert_close(&out[1], &new_v, 1e-5, 1e-4).unwrap();
        crate::testing::prop::assert_close(&out[2], &delta.data, 1e-4, 1e-3).unwrap();
    }
}
