//! Artifact manifest: the ABI contract emitted by python/compile/aot.py.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// As a (rows, cols) matrix shape; 1-d params are (1, n).
    pub fn matrix_shape(&self) -> (usize, usize) {
        match self.shape.len() {
            1 => (1, self.shape[0]),
            2 => (self.shape[0], self.shape[1]),
            _ => panic!("unsupported param rank for {}", self.name),
        }
    }
}

#[derive(Clone, Debug)]
pub struct KernelEntry {
    pub dim: usize,
    pub n: usize,
    pub rank: usize,
    pub alpha: f32,
    pub file: String,
}

/// Parsed manifest_<preset>.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub preset: String,
    pub hidden: usize,
    pub intermediate: usize,
    pub heads: usize,
    pub layers: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub n_params: usize,
    pub params: Vec<ParamSpec>,
    pub artifacts: BTreeMap<String, String>,
    pub kernels: Vec<KernelEntry>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading manifest {:?}", path.as_ref()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;
        let get_usize = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("manifest missing {k}"))
        };
        let params = j
            .get("params")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest missing params"))?
            .iter()
            .map(|p| {
                let name = p
                    .get("name")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("param missing name"))?
                    .to_string();
                let shape = p
                    .get("shape")
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| anyhow!("param missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect();
                Ok(ParamSpec { name, shape })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut artifacts = BTreeMap::new();
        if let Some(Json::Obj(map)) = j.get("artifacts") {
            for (k, v) in map {
                if let Some(s) = v.as_str() {
                    artifacts.insert(k.clone(), s.to_string());
                }
            }
        }
        let mut kernels = Vec::new();
        if let Some(arr) = j.get("kernels").and_then(|v| v.as_arr()) {
            for k in arr {
                kernels.push(KernelEntry {
                    dim: k.get("dim").and_then(|v| v.as_usize()).unwrap_or(0),
                    n: k.get("n").and_then(|v| v.as_usize()).unwrap_or(0),
                    rank: k.get("rank").and_then(|v| v.as_usize()).unwrap_or(0),
                    alpha: k.get("alpha").and_then(|v| v.as_f64()).unwrap_or(0.25) as f32,
                    file: k
                        .get("file")
                        .and_then(|v| v.as_str())
                        .unwrap_or("")
                        .to_string(),
                });
            }
        }
        Ok(Manifest {
            preset: j
                .get("preset")
                .and_then(|v| v.as_str())
                .unwrap_or("?")
                .to_string(),
            hidden: get_usize("hidden")?,
            intermediate: get_usize("intermediate")?,
            heads: get_usize("heads")?,
            layers: get_usize("layers")?,
            vocab: get_usize("vocab")?,
            seq: get_usize("seq")?,
            batch: get_usize("batch")?,
            n_params: get_usize("n_params")?,
            params,
            artifacts,
            kernels,
        })
    }

    /// Find the fused-update kernel artifact for a (dim, n, rank) shape.
    pub fn kernel_for(&self, dim: usize, n: usize, rank: usize) -> Option<&KernelEntry> {
        self.kernels
            .iter()
            .find(|k| k.dim == dim && k.n == n && k.rank == rank)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "preset": "llama-nano", "hidden": 64, "intermediate": 176,
      "heads": 4, "layers": 2, "vocab": 256, "seq": 64, "batch": 4,
      "n_params": 123,
      "params": [
        {"name": "embed.weight", "shape": [256, 64]},
        {"name": "final_norm.weight", "shape": [64]}
      ],
      "artifacts": {"fwd_bwd": "model_llama-nano.hlo.txt"},
      "kernels": [
        {"dim": 64, "n": 176, "rank": 16, "alpha": 0.25,
         "file": "galore_update_64x176x16.hlo.txt"}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.preset, "llama-nano");
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].matrix_shape(), (256, 64));
        assert_eq!(m.params[1].matrix_shape(), (1, 64));
        assert_eq!(m.artifacts["fwd_bwd"], "model_llama-nano.hlo.txt");
        let k = m.kernel_for(64, 176, 16).unwrap();
        assert_eq!(k.file, "galore_update_64x176x16.hlo.txt");
        assert!(m.kernel_for(1, 2, 3).is_none());
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn real_manifest_matches_rust_model_abi() {
        // If artifacts exist, the python-emitted manifest must agree with
        // rust/src/model/llama.rs param_specs byte for byte.
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest_llama-nano.json");
        if !path.exists() {
            return;
        }
        let m = Manifest::load(path).unwrap();
        let cfg = crate::model::LlamaCfg::preset("llama-nano").unwrap();
        let specs = cfg.param_specs();
        assert_eq!(m.params.len(), specs.len());
        for (a, b) in m.params.iter().zip(&specs) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.shape, b.shape);
        }
        assert_eq!(m.n_params, cfg.n_params());
    }
}
