//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The coordinator's hot path only needs PJRT when HLO artifacts have been
//! produced by `make artifacts` (python/compile/aot.py). Every test, bench
//! and example that touches the runtime first checks for the artifact
//! manifest and skips when it is absent, so a dependency-light build can
//! ship a client whose *construction* succeeds and whose *compile/execute*
//! surface returns a descriptive error.
//!
//! To link the real backend, add the `xla` crate to Cargo.toml and replace
//! the `use xla_stub as xla;` alias in `runtime/mod.rs` — the API surface
//! below mirrors the subset of xla-rs the runtime uses, so no other code
//! changes.

use std::fmt;

/// Error type matching the `?`-into-`anyhow` usage in the runtime.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT backend not linked in this build (dependency-light \
         configuration; see rust/src/runtime/xla_stub.rs)"
    ))
}

/// Host literal. The stub carries no data — it only exists so the runtime's
/// marshalling code typechecks; execution paths error before reading it.
#[derive(Debug, Default, Clone)]
pub struct Literal;

impl Literal {
    pub fn scalar(_x: f32) -> Literal {
        Literal
    }

    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module. Parsing requires the backend, so this always errors —
/// callers only reach it when an artifact file exists on disk.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by `PjRtLoadedExecutable::execute`.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// The "device" client. Construction succeeds so `Runtime::cpu()` works in
/// artifact-less environments; only compile/execute are gated.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub (PJRT not linked)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_but_compile_is_gated() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        let err = client.compile(&XlaComputation).unwrap_err();
        assert!(err.to_string().contains("PJRT backend not linked"));
    }

    #[test]
    fn literal_marshalling_paths_typecheck() {
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
        assert!(Literal::vec1(&[1i32]).to_tuple().is_err());
    }
}
