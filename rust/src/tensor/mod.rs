//! Dense tensor substrate.
//!
//! All optimizer and linalg math in the coordinator runs on these types.
//! [`Matrix`] is a row-major dense f32 matrix with a blocked matmul tuned in
//! the §Perf pass; [`Tensor`] is an N-d array used by Tensor-GaLore's mode-k
//! unfoldings. f32 matches the paper's optimizer-state precision (moments are
//! fp32 even in mixed-precision training).

mod matmul;

pub use matmul::{
    dot, matmul, matmul_a_bt, matmul_a_bt_with_plan, matmul_at_b, matmul_at_b_with_plan,
    matmul_with_plan, MatmulPlan,
};

use crate::util::rng::Pcg64;
use std::fmt;

/// Row-major dense f32 matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)?;
        if self.rows * self.cols <= 36 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Pcg64) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut m.data, std);
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on large matrices.
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// C = A · B.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        matmul(self, other)
    }

    /// C = Aᵀ · B without materializing Aᵀ.
    pub fn matmul_at_b(&self, other: &Matrix) -> Matrix {
        matmul_at_b(self, other)
    }

    /// C = A · Bᵀ without materializing Bᵀ.
    pub fn matmul_a_bt(&self, other: &Matrix) -> Matrix {
        matmul_a_bt(self, other)
    }

    pub fn scale(&mut self, s: f32) {
        for x in self.data.iter_mut() {
            *x *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// self += s * other (axpy).
    pub fn add_scaled(&mut self, other: &Matrix, s: f32) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    pub fn frobenius_norm(&self) -> f32 {
        // Accumulate in f64: Frobenius norms of big gradients overflow f32
        // precision surprisingly fast.
        self.data
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt() as f32
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Extract columns [0, k) as a new rows×k matrix.
    pub fn first_cols(&self, k: usize) -> Matrix {
        assert!(k <= self.cols);
        let mut out = Matrix::zeros(self.rows, k);
        for r in 0..self.rows {
            out.row_mut(r)
                .copy_from_slice(&self.row(r)[..k]);
        }
        out
    }

    /// Column c as a Vec.
    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.at(r, c)).collect()
    }

    /// ‖AᵀA − I‖∞ — orthonormality defect of the columns.
    pub fn orthonormality_defect(&self) -> f32 {
        let gram = self.matmul_at_b(self);
        let mut worst = 0f32;
        for i in 0..gram.rows {
            for j in 0..gram.cols {
                let target = if i == j { 1.0 } else { 0.0 };
                worst = worst.max((gram.at(i, j) - target).abs());
            }
        }
        worst
    }
}

/// N-dimensional dense f32 tensor (row-major / C order). Used by
/// Tensor-GaLore for mode-k unfolding of >2-d parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Mode-k unfolding: tensor → matrix of shape (shape[k], numel/shape[k]).
    /// Follows the Kolda & Bader convention (columns ordered by cycling the
    /// remaining modes with earlier modes varying fastest).
    pub fn unfold(&self, mode: usize) -> Matrix {
        assert!(mode < self.ndim());
        let n_k = self.shape[mode];
        let other: usize = self.numel() / n_k;
        let mut out = Matrix::zeros(n_k, other);

        // strides in row-major layout
        let mut strides = vec![1usize; self.ndim()];
        for d in (0..self.ndim() - 1).rev() {
            strides[d] = strides[d + 1] * self.shape[d + 1];
        }
        // Enumerate all elements; compute unfolded column index.
        let mut idx = vec![0usize; self.ndim()];
        for (flat, &v) in self.data.iter().enumerate() {
            // decompose flat -> multi-index (row-major)
            let mut rem = flat;
            for d in 0..self.ndim() {
                idx[d] = rem / strides[d];
                rem %= strides[d];
            }
            let row = idx[mode];
            // Column index mixes the remaining modes; the last-listed mode
            // varies fastest (consistent with `fold` below).
            let mut col = 0usize;
            let mut mult = 1usize;
            for d in (0..self.ndim()).rev() {
                if d == mode {
                    continue;
                }
                col += idx[d] * mult;
                mult *= self.shape[d];
            }
            out.data[row * other + col] = v;
        }
        out
    }

    /// Inverse of [`unfold`]: rebuild a tensor of `shape` from its mode-k
    /// unfolding.
    pub fn fold(mat: &Matrix, mode: usize, shape: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(shape);
        let ndim = shape.len();
        let mut strides = vec![1usize; ndim];
        for d in (0..ndim - 1).rev() {
            strides[d] = strides[d + 1] * shape[d + 1];
        }
        let other: usize = t.numel() / shape[mode];
        assert_eq!(mat.shape(), (shape[mode], other), "fold shape mismatch");
        let mut idx = vec![0usize; ndim];
        for flat in 0..t.numel() {
            let mut rem = flat;
            for d in 0..ndim {
                idx[d] = rem / strides[d];
                rem %= strides[d];
            }
            let row = idx[mode];
            let mut col = 0usize;
            let mut mult = 1usize;
            for d in (0..ndim).rev() {
                if d == mode {
                    continue;
                }
                col += idx[d] * mult;
                mult *= shape[d];
            }
            t.data[flat] = mat.data[row * other + col];
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg64::new(1, 0);
        let m = Matrix::randn(13, 29, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn eye_is_matmul_identity() {
        let mut rng = Pcg64::new(2, 0);
        let m = Matrix::randn(7, 7, 1.0, &mut rng);
        let p = m.matmul(&Matrix::eye(7));
        prop::assert_close(&p.data, &m.data, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        let mut rng = Pcg64::new(3, 0);
        let a = Matrix::randn(11, 5, 1.0, &mut rng);
        let b = Matrix::randn(11, 9, 1.0, &mut rng);
        let fast = a.matmul_at_b(&b);
        let slow = a.transpose().matmul(&b);
        prop::assert_close(&fast.data, &slow.data, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn a_bt_matches_explicit_transpose() {
        let mut rng = Pcg64::new(4, 0);
        let a = Matrix::randn(6, 8, 1.0, &mut rng);
        let b = Matrix::randn(10, 8, 1.0, &mut rng);
        let fast = a.matmul_a_bt(&b);
        let slow = a.matmul(&b.transpose());
        prop::assert_close(&fast.data, &slow.data, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn frobenius_matches_manual() {
        let m = Matrix::from_vec(2, 2, vec![3.0, 0.0, 0.0, 4.0]);
        assert!((m.frobenius_norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn unfold_fold_roundtrip_all_modes() {
        prop::check("unfold/fold roundtrip", 30, |g| {
            let shape = vec![g.usize_in(1, 5), g.usize_in(1, 5), g.usize_in(1, 5)];
            let data = g.matrix(shape.iter().product::<usize>(), 1);
            let t = Tensor::from_vec(&shape, data);
            for mode in 0..3 {
                let unf = t.unfold(mode);
                let back = Tensor::fold(&unf, mode, &shape);
                if back != t {
                    return Err(format!("mode {mode} roundtrip failed for {shape:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn unfold_known_case() {
        // 2x2x2 tensor, values 0..8 in row-major order.
        let t = Tensor::from_vec(&[2, 2, 2], (0..8).map(|x| x as f32).collect());
        // mode-0 unfolding: rows indexed by i, columns by (j,k) with k fastest.
        let u0 = t.unfold(0);
        assert_eq!(u0.shape(), (2, 4));
        assert_eq!(u0.row(0), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(u0.row(1), &[4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn first_cols_extracts() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let f = m.first_cols(2);
        assert_eq!(f.data, vec![1., 2., 4., 5.]);
    }

    #[test]
    fn orthonormality_defect_of_identity_is_zero() {
        assert!(Matrix::eye(5).orthonormality_defect() < 1e-7);
        let mut rng = Pcg64::new(5, 0);
        let m = Matrix::randn(5, 5, 1.0, &mut rng);
        assert!(m.orthonormality_defect() > 0.1);
    }
}
