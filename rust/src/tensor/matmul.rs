//! Blocked, multi-threaded GEMM kernels.
//!
//! The GaLore projection (R = PᵀG) and reprojection (G̃ = P·N) are BLAS-3
//! calls on every layer every step — the L3 native-engine hot path. The
//! kernels here use cache blocking + an 8-wide inner loop the compiler can
//! vectorize, and partition disjoint row-panels of C across the persistent
//! worker pool (`crate::parallel`). Each thread writes its own `&mut`
//! panel and accumulates every output element in exactly the serial order,
//! so parallel results are **bitwise identical** to the single-threaded
//! kernels for any thread count. Block sizes and the parallel cutover are
//! tuned by `benches/throughput.rs` (see EXPERIMENTS.md §Perf).
//!
//! Three variants avoid materializing transposes:
//!   matmul      C = A · B
//!   matmul_at_b C = Aᵀ · B   (projection: P is m×r stored row-major, G m×n)
//!   matmul_a_bt C = A · Bᵀ

use super::Matrix;
use crate::parallel;

/// Below this many FLOPs (2·m·k·n) the kernels stay serial. With the
/// persistent pool, dispatching a region costs a queue push + condvar wake
/// (single-digit µs, measured by throughput §3b `pool_dispatch_noop`) —
/// down from the ~tens-of-µs scoped spawn that forced the old 4e6 cutover.
/// At ~10 GFLOP/s serial, 3e5 FLOPs ≈ 30 µs of work, comfortably above
/// the dispatch cost; the llama-micro projection pair (~2.9 MFLOP each)
/// that the old threshold kept serial now parallelizes (throughput §3,
/// EXPERIMENTS.md §Perf).
const PAR_MIN_FLOPS: f64 = 3.0e5;

/// Tuning parameters for the blocked GEMM. Block defaults were selected by
/// the perf sweep in `benches/throughput.rs` (see EXPERIMENTS.md §Perf).
#[derive(Clone, Copy, Debug)]
pub struct MatmulPlan {
    pub mc: usize, // rows of A per block
    pub kc: usize, // shared dim per block
    pub nc: usize, // cols of B per block
    /// Worker threads for row-panel parallelism; 0 = use the process
    /// default (`parallel::default_threads()`).
    pub threads: usize,
}

impl Default for MatmulPlan {
    fn default() -> Self {
        MatmulPlan {
            mc: 64,
            kc: 256,
            nc: 256,
            threads: 0,
        }
    }
}

impl MatmulPlan {
    /// A plan pinned to one thread (serial reference execution).
    pub fn serial() -> MatmulPlan {
        MatmulPlan {
            threads: 1,
            ..MatmulPlan::default()
        }
    }

    /// A plan pinned to an explicit thread count.
    pub fn with_threads(threads: usize) -> MatmulPlan {
        MatmulPlan {
            threads,
            ..MatmulPlan::default()
        }
    }

    /// Threads to use for an (m, k, n) product: serial below the FLOP
    /// threshold, otherwise the resolved request capped by row count.
    fn threads_for(&self, m: usize, k: usize, n: usize) -> usize {
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        if flops < PAR_MIN_FLOPS {
            return 1;
        }
        parallel::resolve(self.threads).min(m).max(1)
    }
}

/// Rows per parallel panel for an m-row output across `threads` workers.
fn panel_rows(m: usize, threads: usize) -> usize {
    ((m + threads - 1) / threads).max(1)
}

/// C = A (m×k) · B (k×n).
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    matmul_with_plan(a, b, MatmulPlan::default())
}

pub fn matmul_with_plan(a: &Matrix, b: &Matrix, plan: MatmulPlan) -> Matrix {
    assert_eq!(
        a.cols, b.rows,
        "matmul shape mismatch: {}x{} · {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    if c.data.is_empty() {
        return c;
    }
    let threads = plan.threads_for(m, k, n);
    if threads <= 1 {
        mm_panel(a, b, plan, 0, m, &mut c.data);
    } else {
        let rows = panel_rows(m, threads);
        parallel::par_chunks_mut(&mut c.data, rows * n, threads, |ci, panel| {
            mm_panel(a, b, plan, ci * rows, panel.len() / n, panel);
        });
    }
    c
}

/// The blocked kernel for C's rows [row0, row0+rows), writing into the
/// caller-provided panel (local row 0 = global row `row0`). The serial
/// path calls this once with the full range; the parallel path calls it
/// per disjoint panel. Per output element the accumulation order over the
/// shared dim is identical either way (kk blocks ascending, p ascending),
/// which is what makes thread count invisible in the bits.
fn mm_panel(a: &Matrix, b: &Matrix, plan: MatmulPlan, row0: usize, rows: usize, c: &mut [f32]) {
    let (k, n) = (a.cols, b.cols);
    debug_assert_eq!(c.len(), rows * n);
    // i-k-j loop order: the inner j loop streams contiguous rows of B and C,
    // which auto-vectorizes well; blocking keeps the B panel in cache.
    for kk in (0..k).step_by(plan.kc) {
        let k_end = (kk + plan.kc).min(k);
        for ii in (0..rows).step_by(plan.mc) {
            let i_end = (ii + plan.mc).min(rows);
            for jj in (0..n).step_by(plan.nc) {
                let j_end = (jj + plan.nc).min(n);
                for i in ii..i_end {
                    let gi = row0 + i;
                    let a_row = &a.data[gi * k..(gi + 1) * k];
                    let c_row = &mut c[i * n + jj..i * n + j_end];
                    for p in kk..k_end {
                        // NOTE: no `av == 0.0` skip — 0·NaN and 0·Inf must
                        // propagate NaN (IEEE 754), and the old fast-path
                        // silently dropped them (see the regression test).
                        let av = a_row[p];
                        let b_row = &b.data[p * n + jj..p * n + j_end];
                        axpy(c_row, b_row, av);
                    }
                }
            }
        }
    }
}

/// C = Aᵀ (k×m → m taken as a.cols) · B. A is k×m row-major; result is m×n.
/// This is the GaLore projection: R = Pᵀ G with P (m×r) ⇒ call with a=P, b=G.
pub fn matmul_at_b(a: &Matrix, b: &Matrix) -> Matrix {
    matmul_at_b_with_plan(a, b, MatmulPlan::default())
}

pub fn matmul_at_b_with_plan(a: &Matrix, b: &Matrix, plan: MatmulPlan) -> Matrix {
    assert_eq!(
        a.rows, b.rows,
        "matmul_at_b shape mismatch: ({}x{})ᵀ · {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    if c.data.is_empty() {
        return c;
    }
    let threads = plan.threads_for(m, k, n);
    if threads <= 1 {
        atb_panel(a, b, 0, m, &mut c.data);
    } else {
        let rows = panel_rows(m, threads);
        parallel::par_chunks_mut(&mut c.data, rows * n, threads, |ci, panel| {
            atb_panel(a, b, ci * rows, panel.len() / n, panel);
        });
    }
    c
}

/// Aᵀ·B kernel for C's rows [row0, row0+rows) — C rows index A's *columns*,
/// so each panel reads all of A and B but owns a disjoint output slice.
/// Accumulation over the shared index p is ascending exactly as in the
/// serial kernel, preserving bitwise identity.
fn atb_panel(a: &Matrix, b: &Matrix, row0: usize, rows: usize, c: &mut [f32]) {
    let (k, m, n) = (a.rows, a.cols, b.cols);
    debug_assert_eq!(c.len(), rows * n);
    // For each shared index p, rank-1 update C += a_row_pᵀ ⊗ b_row_p.
    // Both a and b rows are contiguous; the inner loop over j vectorizes.
    const KC: usize = 128;
    for pp in (0..k).step_by(KC) {
        let p_end = (pp + KC).min(k);
        for p in pp..p_end {
            let a_row = &a.data[p * m..(p + 1) * m];
            let b_row = &b.data[p * n..(p + 1) * n];
            for i in 0..rows {
                // No zero skip — NaN/Inf in B's row must propagate.
                let av = a_row[row0 + i];
                axpy(&mut c[i * n..(i + 1) * n], b_row, av);
            }
        }
    }
}

/// C = A (m×k) · Bᵀ with B (n×k). Result m×n. Dot-product formulation —
/// both operands stream contiguously.
pub fn matmul_a_bt(a: &Matrix, b: &Matrix) -> Matrix {
    matmul_a_bt_with_plan(a, b, MatmulPlan::default())
}

pub fn matmul_a_bt_with_plan(a: &Matrix, b: &Matrix, plan: MatmulPlan) -> Matrix {
    assert_eq!(
        a.cols, b.cols,
        "matmul_a_bt shape mismatch: {}x{} · ({}x{})ᵀ",
        a.rows, a.cols, b.rows, b.cols
    );
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Matrix::zeros(m, n);
    if c.data.is_empty() {
        return c;
    }
    let threads = plan.threads_for(m, k, n);
    if threads <= 1 {
        abt_panel(a, b, 0, m, &mut c.data);
    } else {
        let rows = panel_rows(m, threads);
        parallel::par_chunks_mut(&mut c.data, rows * n, threads, |ci, panel| {
            abt_panel(a, b, ci * rows, panel.len() / n, panel);
        });
    }
    c
}

fn abt_panel(a: &Matrix, b: &Matrix, row0: usize, rows: usize, c: &mut [f32]) {
    let (k, n) = (a.cols, b.rows);
    debug_assert_eq!(c.len(), rows * n);
    for i in 0..rows {
        let gi = row0 + i;
        let a_row = &a.data[gi * k..(gi + 1) * k];
        for j in 0..n {
            let b_row = &b.data[j * k..(j + 1) * k];
            c[i * n + j] = dot(a_row, b_row);
        }
    }
}

/// y += alpha * x, unrolled 8-wide.
#[inline]
fn axpy(y: &mut [f32], x: &[f32], alpha: f32) {
    debug_assert_eq!(y.len(), x.len());
    let n = y.len();
    let chunks = n / 8;
    // Safety-free manual unroll over exact chunks; the remainder is scalar.
    for c in 0..chunks {
        let base = c * 8;
        let ys = &mut y[base..base + 8];
        let xs = &x[base..base + 8];
        ys[0] += alpha * xs[0];
        ys[1] += alpha * xs[1];
        ys[2] += alpha * xs[2];
        ys[3] += alpha * xs[3];
        ys[4] += alpha * xs[4];
        ys[5] += alpha * xs[5];
        ys[6] += alpha * xs[6];
        ys[7] += alpha * xs[7];
    }
    for i in chunks * 8..n {
        y[i] += alpha * x[i];
    }
}

/// Dot product with 4 independent accumulators (breaks the add dependency
/// chain so the CPU can pipeline).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for c in 0..chunks {
        let i = c * 8;
        s0 += a[i] * b[i] + a[i + 4] * b[i + 4];
        s1 += a[i + 1] * b[i + 1] + a[i + 5] * b[i + 5];
        s2 += a[i + 2] * b[i + 2] + a[i + 6] * b[i + 6];
        s3 += a[i + 3] * b[i + 3] + a[i + 7] * b[i + 7];
    }
    let mut tail = 0f32;
    for i in chunks * 8..n {
        tail += a[i] * b[i];
    }
    s0 + s1 + s2 + s3 + tail
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;
    use crate::util::rng::Pcg64;

    /// Textbook triple loop as oracle.
    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0f32;
                for p in 0..a.cols {
                    s += a.at(i, p) * b.at(p, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    #[test]
    fn matches_naive_on_random_shapes() {
        prop::check("blocked matmul == naive", 40, |g| {
            let (m, k, n) = (g.usize_in(1, 40), g.usize_in(1, 40), g.usize_in(1, 40));
            let a = Matrix::from_vec(m, k, g.matrix(m, k));
            let b = Matrix::from_vec(k, n, g.matrix(k, n));
            let fast = matmul(&a, &b);
            let slow = naive(&a, &b);
            prop::assert_close(&fast.data, &slow.data, 1e-4, 1e-4)
        });
    }

    #[test]
    fn blocking_boundaries_exact() {
        // Shapes straddling every block boundary.
        let mut rng = Pcg64::new(8, 0);
        for &(m, k, n) in &[(63, 255, 255), (64, 256, 256), (65, 257, 257), (1, 1, 1)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let fast = matmul(&a, &b);
            let slow = naive(&a, &b);
            prop::assert_close(&fast.data, &slow.data, 1e-3, 1e-3).unwrap();
        }
    }

    #[test]
    fn custom_plan_same_result() {
        let mut rng = Pcg64::new(9, 0);
        let a = Matrix::randn(30, 70, 1.0, &mut rng);
        let b = Matrix::randn(70, 50, 1.0, &mut rng);
        let base = matmul(&a, &b);
        for &(mc, kc, nc) in &[(8, 8, 8), (16, 64, 32), (128, 512, 512)] {
            let plan = MatmulPlan {
                mc,
                kc,
                nc,
                ..MatmulPlan::default()
            };
            let alt = matmul_with_plan(&a, &b, plan);
            prop::assert_close(&base.data, &alt.data, 1e-4, 1e-4).unwrap();
        }
    }

    #[test]
    fn zero_times_nan_propagates() {
        // Regression: the old `av == 0.0 { continue }` fast path dropped
        // NaN/Inf contributions from B (0·NaN must be NaN per IEEE 754).
        let a = Matrix::from_vec(1, 2, vec![0.0, 1.0]);
        let b = Matrix::from_vec(2, 2, vec![f32::NAN, f32::INFINITY, 1.0, 2.0]);
        let c = matmul(&a, &b);
        assert!(c.at(0, 0).is_nan(), "0·NaN lost: {:?}", c.data);
        assert!(c.at(0, 1).is_nan(), "0·Inf lost: {:?}", c.data);

        // Same property for the Aᵀ·B projection kernel: a = P (2×1) with a
        // zero entry, b rows containing NaN.
        let p = Matrix::from_vec(2, 1, vec![0.0, 1.0]);
        let g = Matrix::from_vec(2, 2, vec![f32::NAN, 1.0, 2.0, 3.0]);
        let r = matmul_at_b(&p, &g);
        assert!(r.at(0, 0).is_nan(), "Aᵀ·B 0·NaN lost: {:?}", r.data);
    }

    #[test]
    fn parallel_bitwise_identical_to_serial() {
        // Above the FLOP cutover so the threaded path actually engages:
        // 2·193·161·201 ≈ 12.5 MFLOP.
        let mut rng = Pcg64::new(10, 0);
        let a = Matrix::randn(193, 161, 1.0, &mut rng);
        let b = Matrix::randn(161, 201, 1.0, &mut rng);
        let serial = matmul_with_plan(&a, &b, MatmulPlan::serial());
        for threads in [2, 3, 4, 8] {
            let par = matmul_with_plan(&a, &b, MatmulPlan::with_threads(threads));
            assert_eq!(
                serial.data, par.data,
                "matmul not bitwise stable at {threads} threads"
            );
        }
    }

    #[test]
    fn parallel_at_b_and_a_bt_bitwise_identical_to_serial() {
        let mut rng = Pcg64::new(11, 0);
        // Aᵀ·B: A is k×m (projection layout), result 180×210.
        let a = Matrix::randn(150, 180, 1.0, &mut rng);
        let b = Matrix::randn(150, 210, 1.0, &mut rng);
        let serial = matmul_at_b_with_plan(&a, &b, MatmulPlan::serial());
        for threads in [2, 4, 7] {
            let par = matmul_at_b_with_plan(&a, &b, MatmulPlan::with_threads(threads));
            assert_eq!(
                serial.data, par.data,
                "matmul_at_b not bitwise stable at {threads} threads"
            );
        }
        // A·Bᵀ: both 170×190-ish.
        let a2 = Matrix::randn(170, 190, 1.0, &mut rng);
        let b2 = Matrix::randn(165, 190, 1.0, &mut rng);
        let serial2 = matmul_a_bt_with_plan(&a2, &b2, MatmulPlan::serial());
        for threads in [2, 4] {
            let par2 = matmul_a_bt_with_plan(&a2, &b2, MatmulPlan::with_threads(threads));
            assert_eq!(
                serial2.data, par2.data,
                "matmul_a_bt not bitwise stable at {threads} threads"
            );
        }
    }

    #[test]
    fn dot_matches_naive() {
        prop::check("dot == naive", 50, |g| {
            let n = g.usize_in(0, 67);
            let a = g.matrix(n.max(1), 1);
            let b = g.matrix(n.max(1), 1);
            let a = &a[..n];
            let b = &b[..n];
            let naive: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
            let fast = dot(a, b);
            if (fast - naive).abs() > 1e-3 + 1e-3 * naive.abs() {
                return Err(format!("dot mismatch {fast} vs {naive} (n={n})"));
            }
            Ok(())
        });
    }
}
