//! Coordinator: the leader process behind every CLI subcommand.
//!
//! Owns run directories, wires trainer + eval harness + memory model
//! together, and prints the human-readable reports. `main.rs` is a thin
//! argument-parsing shell over these entry points so examples and
//! integration tests can drive the same code paths programmatically.

use crate::config::TrainConfig;
use crate::data::{Corpus, CorpusCfg};
use crate::eval::{CategoryResult, EvalHarness};
use crate::memory::{self, MemoryCfg, OptimKind, Parallelism, Precision};
use crate::metrics::ascii_chart;
use crate::model::LlamaCfg;
use crate::runtime::{Manifest, Runtime};
use crate::tensor::Matrix;
use crate::train::{StepEvent, StepObserver, Trainer};
use crate::util::human_bytes;
use anyhow::{Context, Result};

/// Prints validation sweeps and checkpoint writes as they happen — the
/// coordinator consumes the trainer's event stream like any other
/// subscriber instead of polling trainer internals.
pub struct ConsoleObserver;

impl StepObserver for ConsoleObserver {
    fn on_event(&mut self, event: &StepEvent) {
        match event {
            StepEvent::Val { step, loss, .. } => {
                println!("  step {step:>6}  val_loss {loss:.4}  ppl {:.2}", loss.exp());
            }
            StepEvent::Checkpoint { step, path } => {
                println!("  step {step:>6}  checkpoint → {}", path.display());
            }
            StepEvent::WorkerLost { step, rank, cause } => {
                println!("[recover] step {step}: worker rank {rank} lost — {cause}");
            }
            StepEvent::RecoveryStarted {
                from_step,
                old_world,
                new_world,
            } => {
                println!(
                    "[recover] rebuilding cluster: world {old_world} → {new_world}, \
                     re-sharding snapshot from step {from_step}"
                );
            }
            StepEvent::RecoveryComplete { resume_step, world } => {
                println!("[recover] recovered — resuming at step {resume_step} on {world} rank(s)");
            }
            // Train points go through Metrics; the per-step timing and
            // traffic firehoses are too chatty for the console.
            StepEvent::Train { .. }
            | StepEvent::StepTimed { .. }
            | StepEvent::StepTraffic { .. } => {}
        }
    }
}

/// Train per config; writes metrics CSV into the run dir and returns the
/// trainer for further inspection.
pub fn train(cfg: TrainConfig) -> Result<Trainer> {
    train_with(cfg, vec![Box::new(ConsoleObserver)])
}

/// [`train`] with caller-provided [`StepObserver`]s subscribed before the
/// run starts (see `examples/quickstart.rs` for a custom observer).
pub fn train_with(
    cfg: TrainConfig,
    observers: Vec<Box<dyn StepObserver>>,
) -> Result<Trainer> {
    let mut trainer = Trainer::new(cfg)?;
    for obs in observers {
        trainer.add_observer(obs);
    }
    // Elastic restart: the checkpoint may come from any mode/world — v3
    // canonical optimizer state is re-sliced for this run's engine.
    if let Some(path) = trainer.cfg.resume_from.clone() {
        let step = trainer.resume(&path)?;
        println!(
            "resumed {} at step {step} (parallel={} world={})",
            path.display(),
            trainer.engine().name(),
            trainer.engine().world()
        );
    }
    let exec = format!("{:?}", trainer.cfg.engine).to_lowercase();
    println!(
        "run={} preset={} optimizer={} engine={} parallel={} transport={} world={} steps={}",
        trainer.cfg.run_name,
        trainer.cfg.preset,
        trainer.engine().optimizer_name(),
        exec,
        trainer.engine().name(),
        trainer.cfg.transport.name(),
        trainer.engine().world(),
        trainer.cfg.steps
    );
    let outcome = trainer.run()?;
    println!(
        "done: steps={} tokens={} final_train_loss={:.4} final_val_loss={:.4} ppl={:.2} wall={:.1}s",
        outcome.steps,
        outcome.tokens,
        outcome.final_train_loss,
        outcome.final_val_loss,
        outcome.final_val_loss.exp(),
        outcome.wall_secs
    );
    let csv_path = trainer
        .cfg
        .out_dir
        .join(&trainer.cfg.run_name)
        .join("metrics.csv");
    trainer.metrics.write_csv(&csv_path)?;
    println!("metrics → {}", csv_path.display());
    let train_pts: Vec<(u64, f64)> = trainer
        .metrics
        .of_tag("train")
        .map(|p| (p.step, p.loss))
        .collect();
    let val_pts: Vec<(u64, f64)> = trainer
        .metrics
        .of_tag("val")
        .map(|p| (p.step, p.loss))
        .collect();
    if !train_pts.is_empty() {
        println!(
            "{}",
            ascii_chart(&[("train", train_pts), ("val", val_pts)], 72, 14)
        );
    }
    if let Some(reports) = trainer.memory_reports() {
        for (rank, r) in reports.iter().enumerate() {
            println!(
                "rank {rank}: shard={} optim={} transient≤{} traffic={} elems",
                human_bytes(r.param_shard_bytes as u64),
                human_bytes(r.optimizer_bytes as u64),
                human_bytes(r.peak_transient_bytes as u64),
                r.traffic_elems
            );
        }
    }
    Ok(trainer)
}

/// Run the downstream suite (Tables 3–7) on a parameter set.
pub fn eval_params(
    cfg: &TrainConfig,
    params: &[Matrix],
    per_category: usize,
) -> Result<Vec<CategoryResult>> {
    let llama = LlamaCfg::preset(&cfg.preset).context("unknown preset")?;
    let manifest = Manifest::load(
        cfg.artifacts_dir
            .join(format!("manifest_{}.json", cfg.preset)),
    )?;
    let rt = Runtime::cpu()?;
    let forward = rt.load(cfg.artifacts_dir.join(&manifest.artifacts["forward"]))?;
    let corpus = Corpus::new(CorpusCfg {
        vocab: llama.vocab,
        branching: 8,
        order: 1,
        seed: cfg.seed ^ 0xc0de,
    });
    let harness = EvalHarness::new(forward, manifest, corpus);
    let results = harness.run_suite(params, per_category, cfg.seed)?;
    for r in &results {
        println!(
            "{:<24} acc={:.3} (chance {:.3}, n={})",
            r.category.name(),
            r.accuracy,
            r.chance,
            r.n
        );
    }
    Ok(results)
}

/// Print the analytic per-GPU memory table for a preset (Table 1 / §1).
pub fn memory_report(preset: &str, seq: usize, world: usize) -> Result<()> {
    let cfg = LlamaCfg::preset(preset).context("unknown preset")?;
    println!(
        "Memory model — {} ({} params), seq={}, batch=1, {} GPU(s) FSDP",
        cfg.name,
        crate::util::human_count(cfg.n_params() as u64),
        seq,
        world
    );
    println!(
        "{:<28} {:>10} {:>10} {:>10} {:>10} {:>10} {:>12}",
        "configuration", "params", "master", "grads", "optim", "activ", "TOTAL"
    );
    let rank = cfg.default_rank();
    let rows: Vec<(&str, OptimKind, bool)> = vec![
        ("AdamW + FSDP", OptimKind::AdamW, false),
        ("Adam8bit + FSDP", OptimKind::Adam8bit, false),
        ("GaLore + FSDP", OptimKind::GaLore { rank }, true),
        ("GaLore8bit + FSDP", OptimKind::GaLore8bit { rank }, true),
        // Stored-size accounting: int8 projector codes + block scales.
        ("QGaLore + FSDP", OptimKind::QGaLore { rank }, true),
        ("LoRA + FSDP", OptimKind::Lora { rank }, false),
    ];
    for (name, optim, per_layer) in rows {
        let est = memory::estimate(
            &cfg,
            &MemoryCfg {
                optim,
                parallelism: Parallelism::Fsdp { world },
                precision: Precision::mixed_bf16(),
                seq,
                batch: 1,
                per_layer_update: per_layer,
                activation_factor: 0.3,
            },
        );
        println!(
            "{:<28} {:>10} {:>10} {:>10} {:>10} {:>10} {:>12}",
            name,
            human_bytes(est.params),
            human_bytes(est.master_weights),
            human_bytes(est.grads),
            human_bytes(est.optimizer),
            human_bytes(est.activations),
            format!("{:.2} GiB", est.total_gib()),
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ParallelMode;

    fn artifacts_ready() -> bool {
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest_llama-nano.json")
            .exists()
    }

    fn quick_cfg(optimizer: &str, steps: u64) -> TrainConfig {
        TrainConfig {
            preset: "llama-nano".into(),
            artifacts_dir: std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
                .join("artifacts"),
            out_dir: std::env::temp_dir().join("galore2_coord_test"),
            run_name: format!("t_{optimizer}_{}", std::process::id()),
            optimizer: optimizer.into(),
            steps,
            lr: 0.01,
            galore_rank: 16,
            galore_update_freq: 20,
            eval_every: 0,
            eval_batches: 2,
            log_every: 5,
            corpus_tokens: 20_000,
            val_tokens: 4_000,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn trains_nano_galore_loss_decreases() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts missing");
            return;
        }
        let mut cfg = quick_cfg("galore", 100);
        cfg.lr = 0.1; // α=0.25 ⇒ effective projected lr 0.025
        let mut trainer = Trainer::new(cfg).unwrap();
        let first = trainer.train_step(0).unwrap();
        let mut last = first;
        for t in 1..100 {
            last = trainer.train_step(t).unwrap();
        }
        assert!(
            last < first - 0.5,
            "no learning: first {first} last {last}"
        );
    }

    #[test]
    fn fsdp_mode_trains() {
        if !artifacts_ready() {
            return;
        }
        let mut cfg = quick_cfg("galore", 10);
        cfg.parallel = ParallelMode::Fsdp;
        cfg.world = 2;
        let mut trainer = Trainer::new(cfg).unwrap();
        let first = trainer.train_step(0).unwrap();
        let mut last = first;
        for t in 1..10 {
            last = trainer.train_step(t).unwrap();
        }
        assert!(last < first, "no learning under FSDP: {first} -> {last}");
        assert!(trainer.memory_reports().is_some());
    }

    #[test]
    fn memory_report_runs() {
        memory_report("llama3-8b", 2048, 2).unwrap();
    }
}
