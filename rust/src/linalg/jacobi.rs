//! Cyclic Jacobi eigendecomposition for symmetric matrices.
//!
//! Used by [`super::svd`] on the Gram matrix A·Aᵀ. Jacobi is slower than
//! tridiagonal QR asymptotically but is simple, famously accurate for small
//! eigenvalues, and deterministic — exactly what the "expensive baseline"
//! role in the paper's §4.1.2 comparison needs.

use crate::tensor::Matrix;

/// Eigendecomposition of symmetric `a`: returns (eigenvalues ascending,
/// eigenvectors as columns of the returned matrix), a = V diag(λ) Vᵀ.
pub fn jacobi_eigh(a: &Matrix) -> (Vec<f32>, Matrix) {
    assert_eq!(a.rows, a.cols, "jacobi_eigh needs a square matrix");
    let n = a.rows;
    // Work in f64: the Gram matrix squares the condition number, so f32
    // accumulation loses the small singular values GaLore's tail analysis
    // cares about.
    let mut m: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut v = vec![0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    let max_sweeps = 30;
    for _sweep in 0..max_sweeps {
        // Off-diagonal Frobenius norm for convergence test.
        let mut off = 0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[i * n + j] * m[i * n + j];
            }
        }
        if off.sqrt() < 1e-11 * frob(&m, n).max(1e-300) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                // Rotation angle annihilating (p,q).
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // Apply rotation J(p,q,θ)ᵀ M J(p,q,θ) in place.
                for k in 0..n {
                    let mkp = m[k * n + p];
                    let mkq = m[k * n + q];
                    m[k * n + p] = c * mkp - s * mkq;
                    m[k * n + q] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[p * n + k];
                    let mqk = m[q * n + k];
                    m[p * n + k] = c * mpk - s * mqk;
                    m[q * n + k] = s * mpk + c * mqk;
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }

    // Extract and sort ascending.
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[i * n + i], i)).collect();
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let evals: Vec<f32> = pairs.iter().map(|&(l, _)| l as f32).collect();
    let mut evecs = Matrix::zeros(n, n);
    for (new_col, &(_, old_col)) in pairs.iter().enumerate() {
        for r in 0..n {
            *evecs.at_mut(r, new_col) = v[r * n + old_col] as f32;
        }
    }
    (evals, evecs)
}

fn frob(m: &[f64], n: usize) -> f64 {
    let mut s = 0f64;
    for i in 0..n * n {
        s += m[i] * m[i];
    }
    s.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;
    use crate::util::rng::Pcg64;

    fn random_symmetric(n: usize, rng: &mut Pcg64) -> Matrix {
        let a = Matrix::randn(n, n, 1.0, rng);
        let at = a.transpose();
        let mut s = a.clone();
        s.add_assign(&at);
        s.scale(0.5);
        s
    }

    #[test]
    fn reconstructs_symmetric() {
        let mut rng = Pcg64::new(1, 0);
        let a = random_symmetric(9, &mut rng);
        let (evals, v) = jacobi_eigh(&a);
        // rebuild V diag(λ) Vᵀ
        let mut vd = v.clone();
        for r in 0..vd.rows {
            for c in 0..vd.cols {
                *vd.at_mut(r, c) *= evals[c];
            }
        }
        let rec = vd.matmul_a_bt(&v);
        prop::assert_close(&rec.data, &a.data, 1e-4, 1e-3).unwrap();
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let mut rng = Pcg64::new(2, 0);
        let a = random_symmetric(12, &mut rng);
        let (_, v) = jacobi_eigh(&a);
        assert!(v.orthonormality_defect() < 1e-4);
    }

    #[test]
    fn diagonal_matrix_exact() {
        let mut a = Matrix::zeros(4, 4);
        for (i, &l) in [4.0f32, -1.0, 2.5, 0.0].iter().enumerate() {
            *a.at_mut(i, i) = l;
        }
        let (evals, _) = jacobi_eigh(&a);
        let expect = [-1.0, 0.0, 2.5, 4.0];
        for (got, want) in evals.iter().zip(expect) {
            assert!((got - want).abs() < 1e-6, "{evals:?}");
        }
    }

    #[test]
    fn gram_matrix_psd_eigenvalues() {
        prop::check("gram eigenvalues nonneg", 15, |g| {
            let (m, n) = (g.usize_in(2, 10), g.usize_in(2, 10));
            let a = Matrix::from_vec(m, n, g.matrix(m, n));
            let gram = a.matmul_a_bt(&a);
            let (evals, _) = jacobi_eigh(&gram);
            for &l in &evals {
                if l < -1e-2 {
                    return Err(format!("negative eigenvalue {l}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn trace_preserved() {
        let mut rng = Pcg64::new(3, 0);
        let a = random_symmetric(8, &mut rng);
        let trace: f32 = (0..8).map(|i| a.at(i, i)).sum();
        let (evals, _) = jacobi_eigh(&a);
        let sum: f32 = evals.iter().sum();
        assert!((trace - sum).abs() < 1e-4, "trace {trace} vs λ-sum {sum}");
    }
}
