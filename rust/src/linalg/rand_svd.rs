//! Randomized truncated SVD (Halko, Martinsson & Tropp 2011).
//!
//! This is the paper's §4.1.2 contribution: subspace updates via full SVD
//! cost ~20 minutes per refresh on Llama-7B matrices; the randomized
//! algorithm is ~15× faster with no accuracy loss at GaLore's ranks.
//!
//! Algorithm (HMT Alg. 4.3 + 5.1):
//!   1. Sketch:     Y = (A Aᵀ)^q A Ω,  Ω ∈ ℝ^{n×(r+p)} Gaussian
//!   2. Range:      Q = qr(Y).Q                      (m × (r+p))
//!   3. Project:    B = Qᵀ A                         ((r+p) × n)
//!   4. Small SVD:  B = Ũ S Vᵀ;  U = Q Ũ, truncate to r.
//!
//! `p` is oversampling (default 8), `q` power iterations (default 1, enough
//! for the sharply-decaying gradient spectra GaLore exploits).
//!
//! The tall-matrix products (A·Ω, A·Qz, Aᵀ·Q) dominate the refresh cost at
//! gradient scale; they run through the multi-threaded GEMM kernels
//! (`tensor::matmul`), which fan row-panels across the persistent worker pool
//! above the size cutover while staying bitwise identical to serial — so
//! `deterministic_given_rng_state` holds for every thread count.

use super::{fix_signs, qr_q_only, svd, Svd};
use crate::tensor::Matrix;
use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug)]
pub struct RandSvdOpts {
    /// Oversampling columns beyond the target rank.
    pub oversample: usize,
    /// Subspace/power iterations (each adds one A·Aᵀ multiply, sharpening
    /// the spectrum; 1–2 suffice in practice).
    pub power_iters: usize,
}

impl Default for RandSvdOpts {
    fn default() -> Self {
        RandSvdOpts {
            oversample: 8,
            power_iters: 1,
        }
    }
}

/// Orthonormal basis approximating the range of `a` with `sketch_cols`
/// columns (HMT Alg. 4.3 with re-orthonormalization between power steps).
pub fn randomized_range_finder(
    a: &Matrix,
    sketch_cols: usize,
    power_iters: usize,
    rng: &mut Pcg64,
) -> Matrix {
    let (_m, n) = a.shape();
    let omega = Matrix::randn(n, sketch_cols, 1.0, rng);
    let mut y = a.matmul(&omega); // m × k
    let mut q = qr_q_only(&y);
    for _ in 0..power_iters {
        // Re-orthonormalize on both sides for numerical stability
        // (HMT Alg. 4.4 — plain powering loses the small directions).
        let z = a.matmul_at_b(&q); // n × k  (Aᵀ Q)
        let qz = qr_q_only(&z);
        y = a.matmul(&qz); // m × k
        q = qr_q_only(&y);
    }
    q
}

/// Truncated rank-`rank` SVD of `a` via randomized range finding.
pub fn randomized_svd(a: &Matrix, rank: usize, opts: RandSvdOpts, rng: &mut Pcg64) -> Svd {
    let (m, n) = a.shape();
    let k = (rank + opts.oversample).min(m.min(n));
    if m <= n {
        let q = randomized_range_finder(a, k, opts.power_iters, rng); // m×k
        let b = q.matmul_at_b(a); // k×n (Qᵀ A)
        let small = svd(&b); // k ≪ m so this is cheap
        let mut out = Svd {
            u: q.matmul(&small.u), // m×k
            s: small.s,
            vt: small.vt,
        }
        .truncate(rank.min(k));
        fix_signs(&mut out);
        out
    } else {
        // Tall matrix: factor Aᵀ (wide) and swap. Re-apply the §4.1.3
        // dominant-entry-of-U convention on the swapped factors so tall
        // and wide inputs agree (same fix as `linalg::svd`).
        let at = a.transpose();
        let s_t = randomized_svd(&at, rank, opts, rng);
        let mut out = Svd {
            u: s_t.vt.transpose(),
            s: s_t.s,
            vt: s_t.u.transpose(),
        };
        fix_signs(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rank_r_error;
    use crate::testing::prop;

    fn low_rank(m: usize, n: usize, rank: usize, rng: &mut Pcg64) -> Matrix {
        let b = Matrix::randn(m, rank, 1.0, rng);
        let c = Matrix::randn(rank, n, 1.0, rng);
        b.matmul(&c)
    }

    #[test]
    fn exact_on_low_rank_input() {
        let mut rng = Pcg64::new(1, 0);
        let a = low_rank(24, 40, 4, &mut rng);
        let s = randomized_svd(&a, 4, RandSvdOpts::default(), &mut rng);
        let rec = s.reconstruct();
        let err = a.sub(&rec).frobenius_norm() / a.frobenius_norm();
        assert!(err < 1e-3, "relative err {err}");
    }

    #[test]
    fn near_optimal_on_full_rank_input() {
        // HMT Thm 10.6: error within small factor of best rank-r error.
        let mut rng = Pcg64::new(2, 0);
        let a = Matrix::randn(30, 50, 1.0, &mut rng);
        let r = 10;
        let s = randomized_svd(&a, r, RandSvdOpts { oversample: 10, power_iters: 2 }, &mut rng);
        let err = a.sub(&s.reconstruct()).frobenius_norm();
        let best = rank_r_error(&a, r);
        assert!(err <= best * 1.15, "err {err} vs best {best}");
    }

    #[test]
    fn singular_values_match_full_svd() {
        let mut rng = Pcg64::new(3, 0);
        let a = low_rank(20, 32, 6, &mut rng);
        let full = svd(&a);
        let fast = randomized_svd(&a, 6, RandSvdOpts::default(), &mut rng);
        for i in 0..6 {
            let rel = (full.s[i] - fast.s[i]).abs() / full.s[i].max(1e-6);
            assert!(rel < 1e-3, "s[{i}]: {} vs {}", full.s[i], fast.s[i]);
        }
    }

    #[test]
    fn projector_columns_orthonormal() {
        prop::check("rand-svd U orthonormal", 15, |g| {
            let m = g.usize_in(4, 24);
            let n = g.usize_in(4, 24);
            let r = g.usize_in(1, m.min(n));
            let a = Matrix::from_vec(m, n, g.matrix(m, n));
            let s = randomized_svd(&a, r, RandSvdOpts::default(), &mut Pcg64::new(9, 1));
            let defect = s.u.orthonormality_defect();
            if defect > 1e-3 {
                return Err(format!("defect {defect} (m={m} n={n} r={r})"));
            }
            Ok(())
        });
    }

    #[test]
    fn tall_matrix_handled() {
        let mut rng = Pcg64::new(4, 0);
        let a = low_rank(50, 12, 3, &mut rng);
        let s = randomized_svd(&a, 3, RandSvdOpts::default(), &mut rng);
        assert_eq!(s.u.shape(), (50, 3));
        assert_eq!(s.vt.shape(), (3, 12));
        let err = a.sub(&s.reconstruct()).frobenius_norm() / a.frobenius_norm();
        assert!(err < 1e-3, "err {err}");
    }

    #[test]
    fn range_finder_captures_column_space() {
        let mut rng = Pcg64::new(5, 0);
        let a = low_rank(30, 40, 5, &mut rng);
        let q = randomized_range_finder(&a, 8, 1, &mut rng);
        // ‖A − QQᵀA‖ should be ~0 for rank-5 input with 8 sketch columns.
        let qta = q.matmul_at_b(&a);
        let proj = q.matmul(&qta);
        let err = a.sub(&proj).frobenius_norm() / a.frobenius_norm();
        assert!(err < 1e-3, "range capture err {err}");
    }

    #[test]
    fn deterministic_given_rng_state() {
        let a = low_rank(16, 20, 4, &mut Pcg64::new(6, 0));
        let s1 = randomized_svd(&a, 4, RandSvdOpts::default(), &mut Pcg64::new(7, 0));
        let s2 = randomized_svd(&a, 4, RandSvdOpts::default(), &mut Pcg64::new(7, 0));
        assert_eq!(s1.u.data, s2.u.data);
    }
}
