//! Linear algebra substrate for subspace updates.
//!
//! GaLore's projector refresh needs the top-r singular vectors of the
//! gradient. We provide:
//!   * [`qr`]: Householder QR (used by randomized SVD's range finder),
//!   * [`svd`]: full SVD via symmetric Jacobi eigendecomposition of the
//!     Gram matrix (deterministic, no external BLAS/LAPACK),
//!   * [`randomized_svd`]: Halko–Martinsson–Tropp randomized truncated SVD
//!     (§4.1.2 of the paper; 15× faster than full SVD at scale),
//!   * [`fix_signs`]: sign-determinacy convention (§4.1.3).

mod jacobi;
mod qr;
mod rand_svd;

pub use jacobi::jacobi_eigh;
pub use qr::{qr, qr_q_only};
pub use rand_svd::{randomized_range_finder, randomized_svd, RandSvdOpts};

use crate::tensor::Matrix;

/// Result of a (possibly truncated) SVD: A ≈ U · diag(S) · Vᵀ.
#[derive(Clone, Debug)]
pub struct Svd {
    pub u: Matrix,      // m × k
    pub s: Vec<f32>,    // k, descending
    pub vt: Matrix,     // k × n
}

impl Svd {
    /// Reconstruct U · diag(S) · Vᵀ.
    pub fn reconstruct(&self) -> Matrix {
        let mut us = self.u.clone();
        for r in 0..us.rows {
            for c in 0..us.cols {
                *us.at_mut(r, c) *= self.s[c];
            }
        }
        us.matmul(&self.vt)
    }

    /// Truncate to rank r.
    pub fn truncate(&self, r: usize) -> Svd {
        let r = r.min(self.s.len());
        let mut vt = Matrix::zeros(r, self.vt.cols);
        for i in 0..r {
            vt.row_mut(i).copy_from_slice(self.vt.row(i));
        }
        Svd {
            u: self.u.first_cols(r),
            s: self.s[..r].to_vec(),
            vt,
        }
    }
}

/// Full SVD of A (m×n).
///
/// Strategy: eigendecompose the smaller Gram matrix. For m ≤ n,
/// A Aᵀ = U S² Uᵀ (m×m Jacobi), then Vᵀ = S⁻¹ Uᵀ A. For m > n the roles
/// swap. Cost O(min(m,n)³ + mn·min(m,n)) — this is the expensive baseline
/// the paper's randomized SVD replaces.
pub fn svd(a: &Matrix) -> Svd {
    let (m, n) = a.shape();
    if m <= n {
        let gram = a.matmul_a_bt(a); // m×m = A Aᵀ
        let (evals, evecs) = jacobi_eigh(&gram); // ascending
        // Reorder descending; singular values are sqrt of eigenvalues.
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&i, &j| evals[j].partial_cmp(&evals[i]).unwrap());
        let mut u = Matrix::zeros(m, m);
        let mut s = vec![0f32; m];
        for (k, &idx) in order.iter().enumerate() {
            s[k] = evals[idx].max(0.0).sqrt();
            for r in 0..m {
                *u.at_mut(r, k) = evecs.at(r, idx);
            }
        }
        // Vᵀ rows: v_k = (1/s_k) Aᵀ u_k ⇒ Vᵀ = S⁻¹ Uᵀ A.
        let ut_a = u.matmul_at_b(a); // m×n
        let mut vt = ut_a;
        for k in 0..m {
            let inv = if s[k] > f32::EPSILON * 8.0 { 1.0 / s[k] } else { 0.0 };
            for c in 0..n {
                *vt.at_mut(k, c) *= inv;
            }
        }
        let mut out = Svd { u, s, vt };
        fix_signs(&mut out);
        out
    } else {
        // SVD of Aᵀ then swap factors: A = U S Vᵀ ⇔ Aᵀ = V S Uᵀ.
        let at = a.transpose();
        let svd_t = svd(&at);
        let mut out = Svd {
            u: svd_t.vt.transpose(),
            s: svd_t.s,
            vt: svd_t.u.transpose(),
        };
        // The recursive call normalized signs against *its* U (our V); the
        // §4.1.3 convention is dominant-entry-of-U, so re-apply on the
        // swapped factors or tall and wide inputs silently disagree.
        fix_signs(&mut out);
        out
    }
}

/// Deterministic sign convention (§4.1.3): flip each singular pair so the
/// largest-magnitude entry of the U column is positive. Removes the SVD sign
/// indeterminacy that destabilizes frequent subspace updates (the same
/// convention scikit-learn's `svd_flip` applies).
pub fn fix_signs(svd: &mut Svd) {
    let k = svd.s.len();
    for c in 0..k {
        // find dominant entry of column c of U
        let mut best = 0f32;
        let mut best_val = 0f32;
        for r in 0..svd.u.rows {
            let v = svd.u.at(r, c);
            if v.abs() > best {
                best = v.abs();
                best_val = v;
            }
        }
        if best_val < 0.0 {
            for r in 0..svd.u.rows {
                *svd.u.at_mut(r, c) = -svd.u.at(r, c);
            }
            if c < svd.vt.rows {
                for j in 0..svd.vt.cols {
                    *svd.vt.at_mut(c, j) = -svd.vt.at(c, j);
                }
            }
        }
    }
}

/// Best rank-r approximation error ‖A − A_r‖_F via full SVD (test oracle).
pub fn rank_r_error(a: &Matrix, r: usize) -> f32 {
    let s = svd(a);
    s.s.iter()
        .skip(r)
        .map(|&x| (x as f64) * (x as f64))
        .sum::<f64>()
        .sqrt() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;
    use crate::util::rng::Pcg64;

    fn reconstruct_close(a: &Matrix, s: &Svd, tol: f32) {
        let rec = s.reconstruct();
        let err = prop::max_abs_diff(&a.data, &rec.data);
        let scale = a.max_abs().max(1.0);
        assert!(err < tol * scale, "reconstruction err {err} (scale {scale})");
    }

    #[test]
    fn svd_reconstructs_wide() {
        let mut rng = Pcg64::new(1, 0);
        let a = Matrix::randn(8, 20, 1.0, &mut rng);
        let s = svd(&a);
        assert_eq!(s.u.shape(), (8, 8));
        assert_eq!(s.vt.shape(), (8, 20));
        reconstruct_close(&a, &s, 1e-3);
    }

    #[test]
    fn svd_reconstructs_tall() {
        let mut rng = Pcg64::new(2, 0);
        let a = Matrix::randn(20, 8, 1.0, &mut rng);
        let s = svd(&a);
        assert_eq!(s.u.shape(), (20, 8));
        assert_eq!(s.vt.shape(), (8, 8));
        reconstruct_close(&a, &s, 1e-3);
    }

    #[test]
    fn singular_values_descending_nonnegative() {
        prop::check("svd s descending", 20, |g| {
            let (m, n) = (g.usize_in(2, 12), g.usize_in(2, 12));
            let a = Matrix::from_vec(m, n, g.matrix(m, n));
            let s = svd(&a);
            for w in s.s.windows(2) {
                if w[1] > w[0] + 1e-4 {
                    return Err(format!("not descending: {:?}", s.s));
                }
            }
            if s.s.iter().any(|&x| x < 0.0) {
                return Err("negative singular value".into());
            }
            Ok(())
        });
    }

    #[test]
    fn u_columns_orthonormal() {
        let mut rng = Pcg64::new(3, 0);
        let a = Matrix::randn(10, 24, 1.0, &mut rng);
        let s = svd(&a);
        assert!(s.u.orthonormality_defect() < 1e-3, "defect={}", s.u.orthonormality_defect());
    }

    #[test]
    fn matches_known_diagonal() {
        // diag(3, 2, 1) padded to 3x5.
        let mut a = Matrix::zeros(3, 5);
        *a.at_mut(0, 0) = 3.0;
        *a.at_mut(1, 1) = -2.0; // sign folded into vectors
        *a.at_mut(2, 2) = 1.0;
        let s = svd(&a);
        assert!((s.s[0] - 3.0).abs() < 1e-4);
        assert!((s.s[1] - 2.0).abs() < 1e-4);
        assert!((s.s[2] - 1.0).abs() < 1e-4);
    }

    #[test]
    fn low_rank_matrix_has_small_tail() {
        let mut rng = Pcg64::new(4, 0);
        // rank-3 matrix: product of 16x3 and 3x20
        let b = Matrix::randn(16, 3, 1.0, &mut rng);
        let c = Matrix::randn(3, 20, 1.0, &mut rng);
        let a = b.matmul(&c);
        let s = svd(&a);
        assert!(s.s[2] > 0.1);
        // Gram-matrix SVD loses ~sqrt(eps)·s[0] in the tail; rank gap must
        // still be >100x.
        assert!(s.s[3] < 1e-2 * s.s[0], "s[3]={} s[0]={}", s.s[3], s.s[0]);
    }

    #[test]
    fn fix_signs_dominant_positive_and_reconstruction_kept() {
        // Both aspect ratios: the tall path swaps factors after the
        // recursive wide SVD and must re-apply the §4.1.3 convention
        // (regression: it used to return without fix_signs).
        for (rows, cols, seed) in [(6usize, 9usize, 5u64), (9, 6, 6), (20, 7, 7)] {
            let mut rng = Pcg64::new(seed, 0);
            let a = Matrix::randn(rows, cols, 1.0, &mut rng);
            let s = svd(&a); // fix_signs applied inside
            for c in 0..s.s.len() {
                let col = s.u.col(c);
                let dom = col
                    .iter()
                    .cloned()
                    .max_by(|a, b| a.abs().partial_cmp(&b.abs()).unwrap())
                    .unwrap();
                assert!(
                    dom >= 0.0,
                    "{rows}x{cols}: column {c} dominant sign negative"
                );
            }
            reconstruct_close(&a, &s, 1e-3);
        }
    }

    #[test]
    fn tall_and_wide_svd_share_the_sign_convention() {
        // svd(A) and svd(Aᵀ) describe the same factorization with U and V
        // swapped; under the dominant-entry-of-U convention the tall U must
        // match the wide V up to the convention's own tie behaviour — check
        // via reconstruction and per-column dominant signs on both.
        let mut rng = Pcg64::new(8, 0);
        let a = Matrix::randn(14, 5, 1.0, &mut rng);
        let tall = svd(&a);
        let wide = svd(&a.transpose());
        for c in 0..tall.s.len() {
            assert!((tall.s[c] - wide.s[c]).abs() < 1e-3 * tall.s[0]);
            let dom_tall = tall
                .u
                .col(c)
                .iter()
                .cloned()
                .max_by(|x, y| x.abs().partial_cmp(&y.abs()).unwrap())
                .unwrap();
            assert!(dom_tall >= 0.0, "tall column {c} violates convention");
        }
        reconstruct_close(&a, &tall, 1e-3);
    }

    #[test]
    fn truncate_keeps_top_components() {
        let mut rng = Pcg64::new(6, 0);
        let a = Matrix::randn(10, 14, 1.0, &mut rng);
        let s = svd(&a).truncate(4);
        assert_eq!(s.u.shape(), (10, 4));
        assert_eq!(s.s.len(), 4);
        assert_eq!(s.vt.shape(), (4, 14));
        // Eckart–Young: truncated reconstruction error equals sqrt(sum tail s²).
        let rec = s.reconstruct();
        let err = a.sub(&rec).frobenius_norm();
        let oracle = rank_r_error(&a, 4);
        assert!((err - oracle).abs() < 1e-2 * oracle.max(1.0), "err={err} oracle={oracle}");
    }
}
