//! Householder QR decomposition.
//!
//! The randomized SVD's range finder orthonormalizes the sketch Y = A·Ω with
//! a thin QR; Householder reflections give machine-precision orthonormality
//! (unlike Gram–Schmidt) at the same O(mn²) cost.

use crate::tensor::Matrix;

/// Thin QR of `a` (m×n, m ≥ n is typical): returns (Q m×n with orthonormal
/// columns, R n×n upper triangular) with a = Q·R.
pub fn qr(a: &Matrix) -> (Matrix, Matrix) {
    let (m, n) = a.shape();
    let k = m.min(n);
    // Factor in f64 for orthonormality of the basis the projector uses.
    let mut r: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    // Householder vectors stored per column.
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(k);

    for j in 0..k {
        // Compute the Householder vector for column j below the diagonal.
        let mut norm = 0f64;
        for i in j..m {
            let x = r[i * n + j];
            norm += x * x;
        }
        let norm = norm.sqrt();
        let mut v = vec![0f64; m - j];
        if norm == 0.0 {
            // Zero column: identity reflector.
            v[0] = 1.0;
            vs.push(v);
            continue;
        }
        let x0 = r[j * n + j];
        let alpha = if x0 >= 0.0 { -norm } else { norm };
        for i in j..m {
            v[i - j] = r[i * n + j];
        }
        v[0] -= alpha;
        let vnorm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        if vnorm > 0.0 {
            for x in v.iter_mut() {
                *x /= vnorm;
            }
        } else {
            v[0] = 1.0;
        }
        // Apply H = I − 2vvᵀ to R[j.., j..].
        for col in j..n {
            let mut dot = 0f64;
            for i in j..m {
                dot += v[i - j] * r[i * n + col];
            }
            let dot2 = 2.0 * dot;
            for i in j..m {
                r[i * n + col] -= dot2 * v[i - j];
            }
        }
        vs.push(v);
    }

    // Build thin Q by applying reflectors to the first k columns of I.
    let mut q = vec![0f64; m * k];
    for j in 0..k {
        q[j * k + j] = 1.0;
    }
    for j in (0..k).rev() {
        let v = &vs[j];
        for col in 0..k {
            let mut dot = 0f64;
            for i in j..m {
                dot += v[i - j] * q[i * k + col];
            }
            let dot2 = 2.0 * dot;
            for i in j..m {
                q[i * k + col] -= dot2 * v[i - j];
            }
        }
    }

    let q_mat = Matrix::from_vec(m, k, q.iter().map(|&x| x as f32).collect());
    let mut r_mat = Matrix::zeros(k, n);
    for i in 0..k {
        for j in i..n {
            *r_mat.at_mut(i, j) = r[i * n + j] as f32;
        }
    }
    (q_mat, r_mat)
}

/// Just the orthonormal basis Q of the column space of `a` — what the range
/// finder needs; skips building R.
pub fn qr_q_only(a: &Matrix) -> Matrix {
    qr(a).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;
    use crate::util::rng::Pcg64;

    #[test]
    fn qr_reconstructs() {
        prop::check("QR reconstructs A", 25, |g| {
            let m = g.usize_in(1, 20);
            let n = g.usize_in(1, 20);
            let a = Matrix::from_vec(m, n, g.matrix(m, n));
            let (q, r) = qr(&a);
            let rec = q.matmul(&r);
            prop::assert_close(&rec.data, &a.data, 1e-4, 1e-3)
        });
    }

    #[test]
    fn q_orthonormal_columns() {
        let mut rng = Pcg64::new(1, 0);
        for &(m, n) in &[(20, 5), (16, 16), (7, 3)] {
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let (q, _) = qr(&a);
            assert!(
                q.orthonormality_defect() < 1e-5,
                "({m}x{n}) defect {}",
                q.orthonormality_defect()
            );
        }
    }

    #[test]
    fn r_upper_triangular() {
        let mut rng = Pcg64::new(2, 0);
        let a = Matrix::randn(10, 6, 1.0, &mut rng);
        let (_, r) = qr(&a);
        for i in 0..r.rows {
            for j in 0..i.min(r.cols) {
                assert_eq!(r.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn handles_rank_deficient() {
        // Two identical columns.
        let mut rng = Pcg64::new(3, 0);
        let col = Matrix::randn(8, 1, 1.0, &mut rng);
        let mut a = Matrix::zeros(8, 2);
        for r in 0..8 {
            *a.at_mut(r, 0) = col.at(r, 0);
            *a.at_mut(r, 1) = col.at(r, 0);
        }
        let (q, r) = qr(&a);
        let rec = q.matmul(&r);
        prop::assert_close(&rec.data, &a.data, 1e-4, 1e-3).unwrap();
    }

    #[test]
    fn zero_matrix_ok() {
        let a = Matrix::zeros(5, 3);
        let (q, r) = qr(&a);
        assert_eq!(q.shape(), (5, 3));
        let rec = q.matmul(&r);
        assert!(rec.max_abs() < 1e-7);
    }
}
