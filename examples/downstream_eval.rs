//! Tables 3–7 / Fig. 4 driver: train GaLore + baseline checkpoints, then
//! score the five downstream categories on both.
//!
//!     cargo run --release --example downstream_eval
//!     cargo run --release --example downstream_eval -- --steps 500 \
//!         --questions 100
//!
//! (pretrain_e2e runs the same comparison as part of its end-to-end
//! pipeline; this driver isolates the evaluation half and accepts
//! pre-existing checkpoints via --galore-ckpt/--baseline-ckpt.)

use galore2::checkpoint::Checkpoint;
use galore2::config::TrainConfig;
use galore2::coordinator;
use galore2::tensor::Matrix;
use galore2::util::cli::Args;

fn train_or_load(
    args: &Args,
    flag: &str,
    cfg: TrainConfig,
) -> anyhow::Result<(TrainConfig, Vec<Matrix>)> {
    if let Some(path) = args.get(flag) {
        let ckpt = Checkpoint::load(path)?;
        println!("loaded {} (step {})", path, ckpt.step);
        return Ok((cfg, ckpt.params));
    }
    let trainer = coordinator::train(cfg)?;
    let cfg = trainer.cfg.clone();
    let params = trainer.params().to_vec();
    Ok((cfg, params))
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let preset = args.str_or("preset", "llama-micro");
    let steps = args.u64_or("steps", 400);
    let questions = args.usize_or("questions", 80);

    let base = TrainConfig {
        preset: preset.clone(),
        steps,
        eval_every: 0,
        log_every: (steps / 10).max(1),
        corpus_tokens: 400_000,
        val_tokens: 40_000,
        seed: 7,
        ..TrainConfig::default()
    };
    let (g_cfg, g_params) = train_or_load(
        &args,
        "galore-ckpt",
        TrainConfig {
            run_name: format!("ds-galore-{preset}"),
            optimizer: "galore".into(),
            lr: 0.02,
            galore_rank: 0,
            galore_update_freq: (steps / 4).max(25),
            ..base.clone()
        },
    )?;
    let (b_cfg, b_params) = train_or_load(
        &args,
        "baseline-ckpt",
        TrainConfig {
            run_name: format!("ds-adam8bit-{preset}"),
            optimizer: "adam8bit".into(),
            lr: 0.01,
            ..base
        },
    )?;

    println!("\n=== GaLore checkpoint ===");
    let g = coordinator::eval_params(&g_cfg, &g_params, questions)?;
    println!("\n=== Adam8bit baseline checkpoint ===");
    let b = coordinator::eval_params(&b_cfg, &b_params, questions)?;

    println!("\n=== Tables 3–7 shape: category table ===");
    println!(
        "{:<24} {:>8} {:>9} {:>7}   paper finding",
        "category", "galore", "baseline", "chance"
    );
    let notes = [
        "parity (Table 3: 0.37 vs 0.37)",
        "baseline slightly ahead (Table 4: 0.40 vs 0.41)",
        "GaLore ahead (Table 5: 0.67 vs 0.64)",
        "parity (Table 6: 0.30 vs 0.30)",
        "parity (Table 7: 0.24 vs 0.24)",
    ];
    for ((gr, br), note) in g.iter().zip(&b).zip(notes) {
        println!(
            "{:<24} {:>8.3} {:>9.3} {:>7.3}   {}",
            gr.category.name(),
            gr.accuracy,
            br.accuracy,
            gr.chance,
            note
        );
    }
    let g_avg: f64 = g.iter().map(|r| r.accuracy).sum::<f64>() / g.len() as f64;
    let b_avg: f64 = b.iter().map(|r| r.accuracy).sum::<f64>() / b.len() as f64;
    println!(
        "{:<24} {:>8.3} {:>9.3}   overall parity is the headline claim",
        "AVERAGE", g_avg, b_avg
    );
    Ok(())
}
