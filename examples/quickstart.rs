//! Quickstart: pre-train a tiny Llama with GaLore in ~30 seconds.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Walks the whole public API surface: config → optimizer spec → train
//! engine → observer stream → metrics → downstream eval → checkpoint, on
//! the llama-nano preset.
//!
//! The API in one paragraph: `TrainConfig::optimizer_spec` maps config
//! strings to an `OptimizerSpec` — the single recipe every execution mode
//! builds its optimizer from (`spec.build(...)`; add new optimizer
//! variants there, not at call sites). The trainer wraps a `TrainEngine`
//! (`single` | `fsdp` | `ddp` — same recipe, any mode, per §4.3 of the
//! paper; switch with `cfg.parallel`), and emits `StepEvent`s that
//! `Metrics` and any registered `StepObserver` consume.

use galore2::config::TrainConfig;
use galore2::coordinator;
use galore2::train::{StepEvent, StepObserver};
use galore2::util::human_count;

/// A custom observer: tracks the best validation loss seen so far from the
/// trainer's event stream (no polling of trainer internals).
struct BestValTracker {
    best: f64,
}

impl StepObserver for BestValTracker {
    fn on_event(&mut self, event: &StepEvent) {
        if let StepEvent::Val { step, loss, .. } = event {
            if *loss < self.best {
                self.best = *loss;
                println!("  step {step:>6}  new best val loss {loss:.4}");
            }
        }
    }
}

fn main() -> anyhow::Result<()> {
    // 1. Configure. Everything in TrainConfig can also come from a TOML
    //    file (configs/nano-galore.toml) or CLI flags via the launcher;
    //    `--parallel fsdp|ddp --world N` selects a distributed engine with
    //    no other changes.
    let cfg = TrainConfig {
        preset: "llama-nano".into(),
        run_name: "quickstart".into(),
        optimizer: "galore".into(),
        lr: 0.02,
        steps: 300,
        galore_rank: 16,        // quarter of hidden (64/4)
        galore_update_freq: 50, // subspace refresh period T
        galore_alpha: 0.25,     // scale factor α
        eval_every: 50,
        ..TrainConfig::default()
    };
    let llama = galore2::model::LlamaCfg::preset(&cfg.preset).unwrap();
    println!(
        "quickstart: {} ({} params), GaLore rank {} / hidden {}\n",
        llama.name,
        human_count(llama.n_params() as u64),
        cfg.galore_rank,
        llama.hidden
    );

    // 2. Train, subscribing a custom observer next to the default console
    //    one. The coordinator prints the loss curve and writes
    //    runs/quickstart/metrics.csv.
    let trainer = coordinator::train_with(
        cfg,
        vec![
            Box::new(coordinator::ConsoleObserver),
            Box::new(BestValTracker { best: f64::INFINITY }),
        ],
    )?;

    // 3. Downstream eval: the five-category suite of §6 (Tables 3–7),
    //    scored on the trained parameters (trainer.params() is the
    //    engine's authoritative full view — gathered shards under FSDP).
    println!("\ndownstream suite (40 questions/category):");
    coordinator::eval_params(&trainer.cfg, trainer.params(), 40)?;

    // 4. Checkpoint for later `galore2 eval --checkpoint …`. Resume goes
    //    through TrainEngine::import_state, so FSDP runs restore every
    //    rank's shard-local moments and re-scatter parameters.
    let path = trainer.save_checkpoint(trainer.cfg.steps)?;
    println!("\ncheckpoint → {}", path.display());
    Ok(())
}
