//! Quickstart: pre-train a tiny Llama with GaLore in ~30 seconds.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Walks the whole public API surface: config → trainer → metrics →
//! downstream eval → checkpoint, on the llama-nano preset.

use galore2::config::TrainConfig;
use galore2::coordinator;
use galore2::util::human_count;

fn main() -> anyhow::Result<()> {
    // 1. Configure. Everything in TrainConfig can also come from a TOML
    //    file (configs/nano-galore.toml) or CLI flags via the launcher.
    let cfg = TrainConfig {
        preset: "llama-nano".into(),
        run_name: "quickstart".into(),
        optimizer: "galore".into(),
        lr: 0.02,
        steps: 300,
        galore_rank: 16,       // quarter of hidden (64/4)
        galore_update_freq: 50, // subspace refresh period T
        galore_alpha: 0.25,    // scale factor α
        eval_every: 50,
        ..TrainConfig::default()
    };
    let llama = galore2::model::LlamaCfg::preset(&cfg.preset).unwrap();
    println!(
        "quickstart: {} ({} params), GaLore rank {} / hidden {}\n",
        llama.name,
        human_count(llama.n_params() as u64),
        cfg.galore_rank,
        llama.hidden
    );

    // 2. Train. The coordinator prints the loss curve and writes
    //    runs/quickstart/metrics.csv.
    let trainer = coordinator::train(cfg)?;

    // 3. Downstream eval: the five-category suite of §6 (Tables 3–7),
    //    scored on the trained parameters.
    println!("\ndownstream suite (40 questions/category):");
    coordinator::eval_params(&trainer.cfg, &trainer.params, 40)?;

    // 4. Checkpoint for later `galore2 eval --checkpoint …`.
    trainer.save_checkpoint(trainer.cfg.steps)?;
    println!(
        "\ncheckpoint → {}",
        trainer.checkpoint_path(trainer.cfg.steps).display()
    );
    Ok(())
}
