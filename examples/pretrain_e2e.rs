//! End-to-end headline run: GaLore vs 8-bit Adam (the paper's §5 matchup)
//! on a real training workload through all three layers.
//!
//!     cargo run --release --example pretrain_e2e                # micro
//!     cargo run --release --example pretrain_e2e -- --preset llama-mini \
//!         --steps 400                                           # bigger
//!
//! For each optimizer: full pre-training on the synthetic corpus with the
//! paper's schedule (10% warmup + cosine→10%), validation sweeps, then the
//! five-category downstream suite (§6) on the final parameters — the
//! miniature of Fig. 3 + Fig. 4/Tables 3–7. Results land in
//! runs/e2e-*/metrics.csv and EXPERIMENTS.md cites this driver.

use galore2::config::TrainConfig;
use galore2::coordinator;
use galore2::metrics::ascii_chart;
use galore2::util::cli::Args;
use galore2::util::human_count;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let preset = args.str_or("preset", "llama-micro");
    let steps = args.u64_or("steps", 400);
    let questions = args.usize_or("questions", 60);
    let llama = galore2::model::LlamaCfg::preset(&preset)
        .ok_or_else(|| anyhow::anyhow!("unknown preset {preset}"))?;
    println!(
        "=== pretrain_e2e: {} ({} params), {} steps x {} tokens/step ===\n",
        preset,
        human_count(llama.n_params() as u64),
        steps,
        llama.batch * llama.seq
    );

    let base = TrainConfig {
        preset: preset.clone(),
        steps,
        eval_every: (steps / 20).max(1),
        eval_batches: 8,
        log_every: (steps / 40).max(1),
        corpus_tokens: (steps as usize * llama.batch * llama.seq).max(200_000) / 2,
        val_tokens: 40_000,
        seed: 7,
        ..TrainConfig::default()
    };

    // --- GaLore (rank = hidden/4, randomized SVD, α=0.25) ---------------
    let galore_cfg = TrainConfig {
        run_name: format!("e2e-galore-{preset}"),
        optimizer: "galore".into(),
        lr: args.f32_or("galore-lr", 0.02),
        galore_rank: 0, // auto: hidden/4
        galore_update_freq: (steps / 4).max(25),
        galore_alpha: 0.25,
        ..base.clone()
    };
    let galore = coordinator::train(galore_cfg)?;

    // --- 8-bit Adam baseline (Dettmers et al. 2022) ---------------------
    let baseline_cfg = TrainConfig {
        run_name: format!("e2e-adam8bit-{preset}"),
        optimizer: "adam8bit".into(),
        lr: args.f32_or("baseline-lr", 0.01),
        ..base
    };
    let baseline = coordinator::train(baseline_cfg)?;

    // --- Fig. 3 miniature: overlaid validation curves -------------------
    let g_pts: Vec<(u64, f64)> = galore
        .metrics
        .of_tag("val")
        .map(|p| (p.tokens, p.loss))
        .collect();
    let b_pts: Vec<(u64, f64)> = baseline
        .metrics
        .of_tag("val")
        .map(|p| (p.tokens, p.loss))
        .collect();
    println!("\n=== validation loss vs tokens (Fig. 3 shape) ===");
    println!("{}", ascii_chart(&[("galore", g_pts), ("adam8bit", b_pts)], 72, 16));
    let g_final = galore.metrics.tail_mean_loss("val", 3).unwrap_or(f64::NAN);
    let b_final = baseline.metrics.tail_mean_loss("val", 3).unwrap_or(f64::NAN);
    println!(
        "final val loss: galore {:.4} (ppl {:.2})  vs  adam8bit {:.4} (ppl {:.2})  gap {:+.4}",
        g_final,
        g_final.exp(),
        b_final,
        b_final.exp(),
        g_final - b_final
    );

    // --- Tables 3–7 miniature: downstream suite on both -----------------
    println!("\n=== downstream suite: GaLore ===");
    let g_res = coordinator::eval_params(&galore.cfg, galore.params(), questions)?;
    println!("\n=== downstream suite: Adam8bit baseline ===");
    let b_res = coordinator::eval_params(&baseline.cfg, baseline.params(), questions)?;
    println!("\n=== Fig. 4 shape: per-category comparison ===");
    println!("{:<24} {:>8} {:>9} {:>7}", "category", "galore", "baseline", "chance");
    let mut g_avg = 0.0;
    let mut b_avg = 0.0;
    for (g, b) in g_res.iter().zip(&b_res) {
        println!(
            "{:<24} {:>8.3} {:>9.3} {:>7.3}",
            g.category.name(),
            g.accuracy,
            b.accuracy,
            g.chance
        );
        g_avg += g.accuracy;
        b_avg += b.accuracy;
    }
    println!(
        "{:<24} {:>8.3} {:>9.3}",
        "AVERAGE",
        g_avg / g_res.len() as f64,
        b_avg / b_res.len() as f64
    );
    Ok(())
}
