//! Figure 1 driver: compare projection types across model sizes.
//!
//!     cargo run --release --example projection_sweep            # nano
//!     cargo run --release --example projection_sweep -- \
//!         --presets llama-nano,llama-micro --steps 300
//!
//! Trains one model per (preset × projection kind) with identical data,
//! seed and schedule; prints the per-kind validation losses. The paper's
//! finding to reproduce: rand_svd ≈ svd, q8 close, q4 degrades some,
//! random degrades clearly.

use galore2::config::TrainConfig;
use galore2::train::Trainer;
use galore2::util::cli::Args;

const KINDS: [&str; 5] = ["svd", "rand_svd", "q8", "q4", "random"];

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let presets = args.str_or("presets", "llama-nano");
    let steps = args.u64_or("steps", 250);

    for preset in presets.split(',') {
        println!("\n=== Figure 1 — {preset}, {steps} steps, all projection types ===");
        let hidden = galore2::model::LlamaCfg::preset(preset)
            .ok_or_else(|| anyhow::anyhow!("unknown preset {preset}"))?
            .hidden;
        let mut rows = Vec::new();
        for kind in KINDS {
            let cfg = TrainConfig {
                preset: preset.into(),
                run_name: format!("fig1-{preset}-{kind}"),
                optimizer: "galore".into(),
                lr: 0.02,
                steps,
                galore_rank: hidden / 4,
                galore_update_freq: (steps / 5).max(20),
                galore_alpha: 0.25,
                galore_projection: kind.into(),
                eval_every: (steps / 10).max(1),
                eval_batches: 6,
                log_every: steps,
                corpus_tokens: 300_000,
                val_tokens: 30_000,
                seed: 7,
                ..TrainConfig::default()
            };
            let mut trainer = Trainer::new(cfg)?;
            let outcome = trainer.run()?;
            println!(
                "  {:<9} final val loss {:.4} (ppl {:.2}), wall {:.1}s",
                kind,
                outcome.final_val_loss,
                outcome.final_val_loss.exp(),
                outcome.wall_secs
            );
            rows.push((kind, outcome.final_val_loss));
        }
        let svd_loss = rows.iter().find(|(k, _)| *k == "svd").unwrap().1;
        let rand_loss = rows.iter().find(|(k, _)| *k == "rand_svd").unwrap().1;
        let random_loss = rows.iter().find(|(k, _)| *k == "random").unwrap().1;
        println!("\n  paper claims on this preset:");
        println!(
            "    rand_svd matches svd:   Δ = {:+.4}  ({})",
            rand_loss - svd_loss,
            if (rand_loss - svd_loss).abs() < 0.1 { "✓ reproduced" } else { "✗" }
        );
        println!(
            "    random degrades:        Δ = {:+.4}  ({})",
            random_loss - svd_loss,
            if random_loss > svd_loss + 0.05 { "✓ reproduced" } else { "✗" }
        );
    }
    Ok(())
}
