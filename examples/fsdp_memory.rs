//! Table 1 reproduction driver: per-GPU memory, GaLore+FSDP vs AdamW+FSDP.
//!
//!     cargo run --release --example fsdp_memory
//!
//! Two halves:
//!   1. the analytic model at the paper's scale (Llama3-8B, 2 GPUs,
//!      seq 2048/4096) — regenerates Table 1's rows;
//!   2. a LIVE llama-nano FSDP cluster whose worker threads report actual
//!      byte counters, validating the model's state terms and showing the
//!      per-layer fused-update gradient behaviour (Fig. 2).

use galore2::config::{ParallelMode, TrainConfig};
use galore2::memory::{estimate, MemoryCfg, OptimKind, Parallelism, Precision};
use galore2::model::LlamaCfg;
use galore2::train::Trainer;
use galore2::util::human_bytes;

fn main() -> anyhow::Result<()> {
    // ---------- analytic Table 1 ----------------------------------------
    println!("=== Table 1 (analytic model): Llama3-8B, FSDP x2, batch 1 ===");
    println!(
        "{:<10} {:>6} {:<16} {:>14} {:>14}",
        "model", "seq", "method", "model (GiB)", "paper (GB)"
    );
    let cfg8b = LlamaCfg::preset("llama3-8b").unwrap();
    let rank = cfg8b.default_rank(); // 1024
    let rows: [(&str, usize, OptimKind, bool, &str); 4] = [
        ("Llama3 8B", 4096, OptimKind::GaLore { rank }, true, "77.45"),
        ("Llama3 8B", 4096, OptimKind::AdamW, false, "OOM (/)"),
        ("Llama3 8B", 2048, OptimKind::GaLore { rank }, true, "72.84"),
        ("Llama3 8B", 2048, OptimKind::AdamW, false, "77.64"),
    ];
    for (model, seq, optim, per_layer, paper) in rows {
        let est = estimate(
            &cfg8b,
            &MemoryCfg {
                optim,
                parallelism: Parallelism::Fsdp { world: 2 },
                precision: Precision::mixed_bf16(),
                seq,
                batch: 1,
                per_layer_update: per_layer,
                activation_factor: 0.3,
            },
        );
        let method = match optim {
            OptimKind::AdamW => "AdamW + FSDP",
            _ => "GaLore + FSDP",
        };
        println!(
            "{:<10} {:>6} {:<16} {:>14.2} {:>14}",
            model,
            seq,
            method,
            est.total_gib(),
            paper
        );
    }

    // ---------- §1 single-GPU claims ------------------------------------
    println!("\n=== §1 claims: Llama 7B single GPU, batch 1 ===");
    let cfg7b = LlamaCfg::preset("llama-7b").unwrap();
    let adam = estimate(
        &cfg7b,
        &MemoryCfg {
            optim: OptimKind::AdamW,
            parallelism: Parallelism::Single,
            precision: Precision::full_fp32(),
            seq: 1024,
            batch: 1,
            per_layer_update: false,
            activation_factor: 0.15,
        },
    );
    let galore = estimate(
        &cfg7b,
        &MemoryCfg {
            optim: OptimKind::GaLore8bit { rank: 1024 },
            parallelism: Parallelism::Single,
            precision: Precision {
                param_bytes: 2,
                grad_bytes: 2,
                master_fp32: false,
            },
            seq: 256,
            batch: 1,
            per_layer_update: true,
            activation_factor: 0.15,
        },
    );
    println!(
        "fp32 Adam:        {:>8.1} GiB   (paper: \"at least 58 GB\")",
        adam.total_gib()
    );
    println!(
        "GaLore + 8bit:    {:>8.1} GiB   (paper: fits a 24 GB RTX 4090)",
        galore.total_gib()
    );

    // ---------- live FSDP vs DDP cluster counters ------------------------
    println!("\n=== live validation: llama-nano x4 workers, real byte counters ===");
    for (mode, optimizer) in [
        (ParallelMode::Fsdp, "adamw"),
        (ParallelMode::Fsdp, "galore"),
        (ParallelMode::Ddp, "galore"),
    ] {
        let cfg = TrainConfig {
            preset: "llama-nano".into(),
            run_name: format!("mem-{mode:?}-{optimizer}").to_lowercase(),
            optimizer: optimizer.into(),
            parallel: mode,
            world: 4,
            steps: 12,
            lr: 0.01,
            galore_rank: 16,
            galore_update_freq: 5,
            eval_every: 0,
            corpus_tokens: 30_000,
            val_tokens: 5_000,
            ..TrainConfig::default()
        };
        let mut trainer = Trainer::new(cfg)?;
        for t in 0..12 {
            trainer.train_step(t)?;
        }
        let reports = trainer.memory_reports().unwrap();
        let r0 = &reports[0];
        println!(
            "{:<4} {:<8} rank0: params {:>10}  optimizer {:>10}  transient ≤ {:>10}  traffic {:>10} elems",
            trainer.engine().name(),
            optimizer,
            human_bytes(r0.param_shard_bytes as u64),
            human_bytes(r0.optimizer_bytes as u64),
            human_bytes(r0.peak_transient_bytes as u64),
            r0.traffic_elems,
        );
    }
    println!(
        "\nGaLore's per-rank optimizer bytes under FSDP are a fraction of\n\
         AdamW's — the sharded moments live in the rank-r space while only\n\
         the projector is replicated (§4.3). The DDP row shows the cost the\n\
         paper avoids: a FULL parameter replica and FULL optimizer state on\n\
         every rank."
    );
    Ok(())
}
